//! Bounded MPMC frame queue with drop-oldest backpressure.
//!
//! A real-time video pipeline must shed load rather than grow latency
//! unboundedly: when the accelerator falls behind, the *oldest* queued
//! frame is dropped (its information is stale) and the new one admitted.
//!
//! Conservation invariant (checked by the concurrency suite): every
//! admitted item is eventually popped or evicted by drop-oldest —
//! `pushed() == popped() + dropped() + len()` at any quiescent point, and
//! `close()` never discards items that were already admitted.
//!
//! Poison policy: every lock acquisition recovers from poisoning
//! (`unwrap_or_else(|e| e.into_inner())`). A producer or consumer that
//! panics while holding the queue lock (e.g. inside a `peek_front`
//! closure) mutates nothing the invariant depends on — the deque and
//! counters are updated only on the non-panicking paths — so the state
//! stays consistent and the rest of the scheduler keeps draining instead
//! of cascade-panicking on `PoisonError`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};

/// What happened to a [`BoundedQueue::push`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushOutcome {
    /// Admitted; the queue had room.
    Admitted,
    /// Admitted, evicting the oldest queued item to make room.
    AdmittedDroppedOldest,
    /// Rejected: the queue was already closed. Nothing was admitted and
    /// no counter moved.
    RejectedClosed,
}

impl PushOutcome {
    /// Was the item admitted (with or without an eviction)?
    pub fn admitted(self) -> bool {
        !matches!(self, PushOutcome::RejectedClosed)
    }

    /// Did this admission evict the oldest queued item?
    pub fn dropped_oldest(self) -> bool {
        matches!(self, PushOutcome::AdmittedDroppedOldest)
    }
}

/// Bounded queue; `push` never blocks (drops oldest on overflow), `pop`
/// blocks until an item or shutdown, `try_pop` never blocks.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
    capacity: usize,
    dropped: AtomicU64,
    pushed: AtomicU64,
    popped: AtomicU64,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    /// Take the queue lock, recovering the guard if a previous holder
    /// panicked (see the module-level poison policy).
    fn lock(&self) -> MutexGuard<'_, Inner<T>> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(capacity),
                closed: false,
            }),
            cv: Condvar::new(),
            capacity,
            dropped: AtomicU64::new(0),
            pushed: AtomicU64::new(0),
            popped: AtomicU64::new(0),
        }
    }

    /// Admit an item, dropping the oldest if full; see [`PushOutcome`]
    /// for the three distinguishable results.
    pub fn push(&self, item: T) -> PushOutcome {
        let mut g = self.lock();
        if g.closed {
            return PushOutcome::RejectedClosed;
        }
        let mut outcome = PushOutcome::Admitted;
        if g.items.len() == self.capacity {
            g.items.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
            outcome = PushOutcome::AdmittedDroppedOldest;
        }
        g.items.push_back(item);
        self.pushed.fetch_add(1, Ordering::Relaxed);
        drop(g);
        self.cv.notify_one();
        outcome
    }

    /// Blocking pop; `None` once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.lock();
        loop {
            if let Some(item) = g.items.pop_front() {
                self.popped.fetch_add(1, Ordering::Relaxed);
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Non-blocking pop: `None` when currently empty (closed or not).
    pub fn try_pop(&self) -> Option<T> {
        let mut g = self.lock();
        let item = g.items.pop_front();
        if item.is_some() {
            self.popped.fetch_add(1, Ordering::Relaxed);
        }
        item
    }

    /// Observe the head item (oldest) without removing it. Returns `None`
    /// when the queue is currently empty. The closure runs under the
    /// queue lock — keep it cheap and lock-free.
    pub fn peek_front<R>(&self, f: impl FnOnce(&T) -> R) -> Option<R> {
        let g = self.lock();
        g.items.front().map(f)
    }

    /// Close: wake all consumers; queued items still drain.
    pub fn close(&self) {
        self.lock().closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }

    pub fn len(&self) -> usize {
        self.lock().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Items evicted by drop-oldest admissions.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Items admitted (rejected-after-close pushes do not count).
    pub fn pushed(&self) -> u64 {
        self.pushed.load(Ordering::Relaxed)
    }

    /// Items handed to consumers via `pop`/`try_pop`.
    pub fn popped(&self) -> u64 {
        self.popped.load(Ordering::Relaxed)
    }
}
