//! Bounded MPSC frame queue with drop-oldest backpressure.
//!
//! A real-time video pipeline must shed load rather than grow latency
//! unboundedly: when the accelerator falls behind, the *oldest* queued
//! frame is dropped (its information is stale) and the new one admitted.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

/// Bounded queue; `push` never blocks (drops oldest on overflow), `pop`
/// blocks until an item or shutdown.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
    capacity: usize,
    dropped: AtomicU64,
    pushed: AtomicU64,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(capacity),
                closed: false,
            }),
            cv: Condvar::new(),
            capacity,
            dropped: AtomicU64::new(0),
            pushed: AtomicU64::new(0),
        }
    }

    /// Admit an item, dropping the oldest if full. Returns `true` if a
    /// drop occurred.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return false;
        }
        let mut dropped = false;
        if g.items.len() == self.capacity {
            g.items.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
            dropped = true;
        }
        g.items.push_back(item);
        self.pushed.fetch_add(1, Ordering::Relaxed);
        drop(g);
        self.cv.notify_one();
        dropped
    }

    /// Blocking pop; `None` once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Close: wake all consumers; queued items still drain.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn pushed(&self) -> u64 {
        self.pushed.load(Ordering::Relaxed)
    }
}
