//! Synthetic video source: frames at a configurable offered rate.

use std::time::{Duration, Instant};

use crate::model::VitConfig;
use crate::util::rng::SplitMix64;

/// One video frame, already in the Fig. 4 flattened-patch layout.
#[derive(Debug, Clone)]
pub struct Frame {
    pub id: u64,
    /// Row-major `N_p × (3·P²)`.
    pub patches: Vec<f32>,
    /// When the source emitted it (for end-to-end latency accounting).
    pub emitted_at: Instant,
}

/// Deterministic synthetic camera. Frame contents use the same PRNG
/// stream family as `sim::weights::synthetic_patches`, so a given
/// `(seed, frame_id)` is reproducible across runs and backends.
pub struct FrameSource {
    config: VitConfig,
    seed: u64,
    next_id: u64,
    /// Inter-frame interval (None ⇒ emit as fast as pulled).
    interval: Option<Duration>,
    last_emit: Option<Instant>,
}

impl FrameSource {
    pub fn new(config: VitConfig, seed: u64, offered_fps: Option<f64>) -> FrameSource {
        FrameSource {
            config,
            seed,
            next_id: 0,
            interval: offered_fps.map(|f| Duration::from_secs_f64(1.0 / f)),
            last_emit: None,
        }
    }

    /// Produce the next frame, sleeping to honour the offered rate.
    pub fn next_frame(&mut self) -> Frame {
        if let (Some(interval), Some(last)) = (self.interval, self.last_emit) {
            let elapsed = last.elapsed();
            if elapsed < interval {
                std::thread::sleep(interval - elapsed);
            }
        }
        let frame = self.make_frame(self.next_id);
        self.next_id += 1;
        self.last_emit = Some(Instant::now());
        frame
    }

    /// Generate frame `id` without pacing (pure function of (seed, id)).
    pub fn make_frame(&self, id: u64) -> Frame {
        let np = self.config.num_patches();
        let pin = self.config.in_chans * self.config.patch_size * self.config.patch_size;
        let mut rng = SplitMix64::new(self.seed ^ 0x5EED_F00D ^ id.wrapping_mul(0x9E37));
        let patches = (0..np * pin)
            .map(|_| rng.next_f32_range(-1.0, 1.0))
            .collect();
        Frame {
            id,
            patches,
            emitted_at: Instant::now(),
        }
    }
}
