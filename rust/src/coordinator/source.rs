//! Synthetic video source: frames at a configurable offered rate.
//!
//! All pacing and latency timestamps go through the [`Clock`]
//! abstraction, so a source behaves identically under real time
//! (`WallClock`) and deterministic simulated time (`VirtualClock`).

use crate::model::VitConfig;
use crate::util::rng::SplitMix64;

use super::clock::Clock;

/// One video frame, already in the Fig. 4 flattened-patch layout.
#[derive(Debug, Clone)]
pub struct Frame {
    pub id: u64,
    /// Which stream emitted it (0 for single-stream serving).
    pub stream: usize,
    /// Row-major `N_p × (3·P²)`. May be empty when the consumer declared
    /// it only needs timing (analytic scheduling runs).
    pub patches: Vec<f32>,
    /// Clock timestamp (seconds) when the source emitted it, for
    /// end-to-end latency accounting.
    pub emitted_at: f64,
    /// Dispatch attempts consumed so far (0 on first dispatch; bumped by
    /// the scheduler's fault-recovery re-dispatch path).
    pub attempts: u32,
}

/// Deterministic synthetic camera. Frame contents use the same PRNG
/// stream family as `sim::weights::synthetic_patches`, so a given
/// `(seed, frame_id)` is reproducible across runs and backends.
pub struct FrameSource {
    config: VitConfig,
    seed: u64,
    stream: usize,
    next_id: u64,
    /// Inter-frame interval in seconds (None ⇒ emit as fast as pulled).
    interval: Option<f64>,
    /// Clock time the next frame is due (paced sources only).
    next_due: f64,
}

impl FrameSource {
    pub fn new(config: VitConfig, seed: u64, offered_fps: Option<f64>) -> FrameSource {
        FrameSource {
            config,
            seed,
            stream: 0,
            next_id: 0,
            interval: offered_fps.map(|f| 1.0 / f),
            next_due: 0.0,
        }
    }

    /// Tag every emitted frame with a stream index (multi-stream serving).
    pub fn with_stream(mut self, stream: usize) -> FrameSource {
        self.stream = stream;
        self
    }

    /// Delay the first frame to `offset` seconds after the clock epoch
    /// (staggers multiple streams so their arrivals interleave).
    pub fn with_offset(mut self, offset: f64) -> FrameSource {
        self.next_due = offset;
        self
    }

    pub fn stream(&self) -> usize {
        self.stream
    }

    /// Scheduled emission time (seconds) of frame `idx` for a paced
    /// source — the arrival timetable a virtual-time scheduler replays.
    pub fn due_at(&self, idx: u64) -> f64 {
        self.next_due + self.interval.unwrap_or(0.0) * idx as f64
    }

    /// Produce the next frame, pacing against `clock` to honour the
    /// offered rate and stamping `emitted_at` from it.
    pub fn next_frame(&mut self, clock: &dyn Clock) -> Frame {
        if let Some(interval) = self.interval {
            clock.sleep_until(self.next_due);
            // Schedule-based pacing; re-anchor when the puller lags so a
            // stall is not followed by a burst of stale frames.
            self.next_due += interval;
            let now = clock.now();
            if self.next_due < now {
                self.next_due = now;
            }
        }
        let mut frame = self.make_frame(self.next_id);
        frame.emitted_at = clock.now();
        self.next_id += 1;
        frame
    }

    /// Generate frame `id` without pacing (pure function of (seed, id);
    /// `emitted_at` is left at the epoch for the caller to stamp).
    pub fn make_frame(&self, id: u64) -> Frame {
        let np = self.config.num_patches();
        let pin = self.config.in_chans * self.config.patch_size * self.config.patch_size;
        let mut rng = SplitMix64::new(self.seed ^ 0x5EED_F00D ^ id.wrapping_mul(0x9E37));
        let patches = (0..np * pin)
            .map(|_| rng.next_f32_range(-1.0, 1.0))
            .collect();
        Frame {
            id,
            stream: self.stream,
            patches,
            emitted_at: 0.0,
            attempts: 0,
        }
    }

    /// Frame `id` with no patch payload — for schedulers whose workers
    /// only model timing and never touch the pixels.
    pub fn make_stub(&self, id: u64) -> Frame {
        Frame {
            id,
            stream: self.stream,
            patches: Vec::new(),
            emitted_at: 0.0,
            attempts: 0,
        }
    }
}
