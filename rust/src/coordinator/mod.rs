//! Frame-serving coordinator (L3 runtime path).
//!
//! VAQF's end product is a *real-time* inference accelerator — the paper's
//! contract is "`FR_tgt` frames per second, sustained". This module is the
//! serving layer that exercises that contract end to end, from the
//! original single-stream loop up to multi-stream traffic:
//!
//! ```text
//! FrameSource ──► BoundedQueue (drop-oldest backpressure) ──► worker
//!    (offered FPS)                                        (backend.infer)
//!                                                              │
//!                                     Metrics ◄── latency, drops, achieved FPS
//! ```
//!
//! * [`serve`] — the single-stream, single-worker loop (used by the PJRT
//!   cross-check path, whose client is thread-affine).
//! * [`Scheduler`] — N streams × W workers behind a pluggable
//!   [`DispatchPolicy`], runnable in real time ([`WallClock`]) or as a
//!   deterministic discrete-event simulation ([`VirtualClock`]).
//!
//! Backends implement [`crate::runtime::InferenceBackend`] (single-stream
//! loop) or [`WorkerModel`] (scheduler pool): either the cycle-level FPGA
//! simulator or the analytical latency model from `perf::cycles`.

mod adaptive;
mod clock;
mod metrics;
mod queue;
mod scheduler;
mod server;
mod source;

pub use adaptive::{
    AdaptivePrecision, AdaptivePrecisionBuilder, HysteresisConfig, HysteresisController,
};
pub use clock::{Clock, VirtualClock, WallClock};
pub use metrics::{
    AggregateReport, Metrics, MultiServingReport, ServingReport, StreamReport, StreamStats,
    WorkerReport,
};
pub use queue::{BoundedQueue, PushOutcome};
pub use scheduler::{
    policy_for, AnalyticWorker, DegradeRung, DispatchPolicy, LeastLoaded, RoundRobin, Scheduler,
    SimWorker, StreamConfig, StreamSnapshot, WeightedSla, WorkerModel, WorkerSnapshot,
    POLICY_NAMES,
};
pub use server::{serve, ServeConfig};
pub use source::{Frame, FrameSource};

#[cfg(test)]
mod tests;
