//! Frame-serving coordinator (L3 runtime path).
//!
//! VAQF's end product is a *real-time* inference accelerator — the paper's
//! contract is "`FR_tgt` frames per second, sustained". This module is the
//! serving loop that exercises that contract end to end:
//!
//! ```text
//! FrameSource ──► BoundedQueue (drop-oldest backpressure) ──► worker
//!    (offered FPS)                                        (backend.infer)
//!                                                              │
//!                                     Metrics ◄── latency, drops, achieved FPS
//! ```
//!
//! Backends implement [`crate::runtime::InferenceBackend`]: either the
//! PJRT functional reference or the cycle-level FPGA simulator (which can
//! pace wall-clock to the simulated latency, so the serving report
//! reflects the *accelerator's* real-time behaviour).

mod adaptive;
mod metrics;
mod queue;
mod server;
mod source;

pub use adaptive::AdaptivePrecision;
pub use metrics::{Metrics, ServingReport};
pub use queue::BoundedQueue;
pub use server::{serve, ServeConfig};
pub use source::{Frame, FrameSource};

#[cfg(test)]
mod tests;
