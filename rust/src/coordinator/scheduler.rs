//! Multi-stream, multi-worker serving scheduler.
//!
//! N independent frame sources — each with its own [`BoundedQueue`],
//! offered rate and optional latency SLA — feed a pool of W simulated
//! accelerator workers through a pluggable [`DispatchPolicy`]:
//!
//! ```text
//! stream 0 ─► BoundedQueue ─┐                 ┌─► worker 0 (WorkerModel)
//! stream 1 ─► BoundedQueue ─┼─ DispatchPolicy ┼─► worker 1
//!    …                      │                 │      …
//! stream N ─► BoundedQueue ─┘                 └─► worker W
//!                                │
//!                      per-stream + per-worker + aggregate
//!                      MultiServingReport (p50/p95/p99, drops, SLA)
//! ```
//!
//! Two execution modes share the policies and the metrics:
//!
//! * [`Scheduler::run_virtual`] — a single-threaded discrete-event
//!   simulation over a [`VirtualClock`] stepping in accelerator-cycle
//!   units. Fully deterministic: the report JSON is byte-identical across
//!   runs, and a minute of simulated traffic costs milliseconds of host
//!   time. Service times come from the worker model (cycle-accurate
//!   simulation or the analytical `perf::cycles` latency).
//! * [`Scheduler::run_wall`] — real producer and worker threads over a
//!   [`WallClock`], for live serving. Free workers pull work themselves,
//!   so the policy's stream choice applies and worker selection is
//!   whichever thread frees up first.

use std::collections::{BinaryHeap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::fault::{DowntimeTracker, FaultKind, FaultPlan, FaultSummary, Health, RecoveryConfig};
use crate::obs::{TraceSink, TrackId, TrackKind};
use crate::sim::ModelExecutor;
use crate::util::stats::Summary;
use crate::Cycles;

use super::adaptive::{HysteresisConfig, HysteresisController};
use super::clock::{Clock, VirtualClock, WallClock};
use super::metrics::{
    AggregateReport, MultiServingReport, StreamReport, StreamStats, WorkerReport,
};
use super::queue::BoundedQueue;
use super::source::{Frame, FrameSource};

// ---------------------------------------------------------------------------
// Stream configuration.
// ---------------------------------------------------------------------------

/// One stream's traffic contract.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// Frames offered per second.
    pub offered_fps: f64,
    /// Total frames this stream offers.
    pub frames: u64,
    /// Queue depth before drop-oldest backpressure kicks in.
    pub queue_depth: usize,
    /// End-to-end latency SLA in milliseconds (None ⇒ best effort).
    pub sla_ms: Option<f64>,
}

impl Default for StreamConfig {
    fn default() -> StreamConfig {
        StreamConfig {
            offered_fps: 30.0,
            frames: 90,
            queue_depth: 2,
            sla_ms: None,
        }
    }
}

// ---------------------------------------------------------------------------
// Worker models.
// ---------------------------------------------------------------------------

/// A simulated accelerator instance in the worker pool: consumes one
/// frame, returns the device-latency in seconds.
pub trait WorkerModel: Send {
    fn name(&self) -> String;

    /// Whether frames routed to this worker need their pixel payload
    /// (`false` lets the scheduler skip synthetic patch generation).
    fn needs_patches(&self) -> bool {
        true
    }

    /// Process one frame; returns the device service time in seconds.
    fn service(&mut self, frame: &Frame) -> anyhow::Result<f64>;
}

/// Constant-latency worker from the analytical performance model
/// (`perf::cycles` via a compiled design's predicted frame rate) — the
/// cheap way to study scheduling behaviour at DeiT scale.
pub struct AnalyticWorker {
    pub latency_s: f64,
    pub label: String,
}

impl WorkerModel for AnalyticWorker {
    fn name(&self) -> String {
        format!("analytic:{}", self.label)
    }

    fn needs_patches(&self) -> bool {
        false
    }

    fn service(&mut self, _frame: &Frame) -> anyhow::Result<f64> {
        Ok(self.latency_s)
    }
}

/// Cycle-level simulated-FPGA worker: runs the functional numerics and
/// reports the simulated latency at the device clock.
pub struct SimWorker {
    pub executor: ModelExecutor,
}

impl WorkerModel for SimWorker {
    fn name(&self) -> String {
        format!(
            "sim-fpga:{}@{}",
            self.executor.config().name, self.executor.device().name
        )
    }

    fn service(&mut self, frame: &Frame) -> anyhow::Result<f64> {
        let (logits, trace) = self.executor.run_frame(&frame.patches);
        debug_assert!(logits.iter().all(|v| v.is_finite()));
        Ok(trace.latency_s)
    }
}

// ---------------------------------------------------------------------------
// Dispatch policies.
// ---------------------------------------------------------------------------

/// A stream with at least one waiting frame, as seen by a policy.
/// Snapshots are always presented in ascending `stream` order.
#[derive(Debug, Clone, Copy)]
pub struct StreamSnapshot {
    pub stream: usize,
    /// Frames currently waiting in this stream's queue.
    pub queued: usize,
    /// Emission time (clock seconds) of the oldest waiting frame.
    pub head_emitted_at: f64,
    /// `head_emitted_at + SLA`, or `f64::INFINITY` for best-effort
    /// streams.
    pub head_deadline: f64,
}

/// An idle worker, as seen by a policy.
#[derive(Debug, Clone, Copy)]
pub struct WorkerSnapshot {
    pub worker: usize,
    /// Cumulative busy seconds so far.
    pub busy_s: f64,
    pub served: u64,
}

/// Pairs waiting frames with idle workers. Both methods receive
/// non-empty candidate slices and return a *position* in the slice.
pub trait DispatchPolicy: Send {
    fn name(&self) -> &'static str;
    fn pick_stream(&mut self, ready: &[StreamSnapshot]) -> usize;
    fn pick_worker(&mut self, idle: &[WorkerSnapshot]) -> usize;
}

fn least_busy_worker(idle: &[WorkerSnapshot]) -> usize {
    idle.iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| {
            a.busy_s
                .total_cmp(&b.busy_s)
                .then(a.worker.cmp(&b.worker))
        })
        .map(|(i, _)| i)
        .unwrap()
}

/// Cycle fairly through streams and workers regardless of load.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next_stream: usize,
    next_worker: usize,
}

impl DispatchPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn pick_stream(&mut self, ready: &[StreamSnapshot]) -> usize {
        let pos = ready
            .iter()
            .position(|s| s.stream >= self.next_stream)
            .unwrap_or(0);
        self.next_stream = ready[pos].stream + 1;
        pos
    }

    fn pick_worker(&mut self, idle: &[WorkerSnapshot]) -> usize {
        let pos = idle
            .iter()
            .position(|w| w.worker >= self.next_worker)
            .unwrap_or(0);
        self.next_worker = idle[pos].worker + 1;
        pos
    }
}

/// Serve the deepest queue first (pressure relief); hand frames to the
/// least-busy worker.
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl DispatchPolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn pick_stream(&mut self, ready: &[StreamSnapshot]) -> usize {
        ready
            .iter()
            .enumerate()
            .max_by_key(|(_, s)| (s.queued, std::cmp::Reverse(s.stream)))
            .map(|(i, _)| i)
            .unwrap()
    }

    fn pick_worker(&mut self, idle: &[WorkerSnapshot]) -> usize {
        least_busy_worker(idle)
    }
}

/// Earliest-deadline-first across streams: the head frame closest to
/// violating its SLA goes next (best-effort streams rank last, oldest
/// first); least-busy worker.
#[derive(Debug, Default)]
pub struct WeightedSla;

impl DispatchPolicy for WeightedSla {
    fn name(&self) -> &'static str {
        "weighted-sla"
    }

    fn pick_stream(&mut self, ready: &[StreamSnapshot]) -> usize {
        ready
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.head_deadline
                    .total_cmp(&b.head_deadline)
                    .then(a.head_emitted_at.total_cmp(&b.head_emitted_at))
                    .then(a.stream.cmp(&b.stream))
            })
            .map(|(i, _)| i)
            .unwrap()
    }

    fn pick_worker(&mut self, idle: &[WorkerSnapshot]) -> usize {
        least_busy_worker(idle)
    }
}

/// Look up a policy by CLI name (`round-robin`/`rr`, `least-loaded`/`ll`,
/// `weighted-sla`/`sla`).
pub fn policy_for(name: &str) -> Option<Box<dyn DispatchPolicy>> {
    match name {
        "round-robin" | "rr" => Some(Box::new(RoundRobin::default())),
        "least-loaded" | "ll" => Some(Box::new(LeastLoaded)),
        "weighted-sla" | "sla" => Some(Box::new(WeightedSla)),
        _ => None,
    }
}

/// The policy names [`policy_for`] accepts (canonical spellings).
pub const POLICY_NAMES: [&str; 3] = ["round-robin", "least-loaded", "weighted-sla"];

// ---------------------------------------------------------------------------
// The scheduler.
// ---------------------------------------------------------------------------

/// One rung of the analytic degrade ladder ([`Scheduler::degrade`]): a
/// precision label and the service-time multiplier relative to the
/// worker's base latency (rung 0 is the compiled full-precision design,
/// scale 1.0; lower rungs are faster, scale < 1).
#[derive(Debug, Clone)]
pub struct DegradeRung {
    pub label: String,
    pub scale: f64,
}

/// The scheduler's precision-degradation state: the rung table plus the
/// shared hysteresis rule from [`super::AdaptivePrecision`].
struct DegradeLadder {
    rungs: Vec<DegradeRung>,
    controller: HysteresisController,
}

/// A configured multi-stream serving run; consume it with
/// [`Scheduler::run_virtual`] or [`Scheduler::run_wall`].
pub struct Scheduler {
    streams: Vec<StreamConfig>,
    sources: Vec<FrameSource>,
    workers: Vec<Box<dyn WorkerModel>>,
    policy: Box<dyn DispatchPolicy>,
    /// Wall mode only: additionally sleep each frame's device latency, so
    /// host-fast simulation serves at the accelerator's real-time rate.
    realtime: bool,
    /// Fault injection schedule (virtual clock only).
    faults: Option<FaultPlan>,
    degrade: Option<DegradeLadder>,
}

impl Scheduler {
    /// `streams[i]` is paired with `sources[i]` (same order, same length).
    pub fn new(
        streams: Vec<(StreamConfig, FrameSource)>,
        workers: Vec<Box<dyn WorkerModel>>,
        policy: Box<dyn DispatchPolicy>,
    ) -> Scheduler {
        assert!(!streams.is_empty(), "scheduler needs at least one stream");
        assert!(!workers.is_empty(), "scheduler needs at least one worker");
        let (streams, sources) = streams.into_iter().unzip();
        Scheduler {
            streams,
            sources,
            workers,
            policy,
            realtime: false,
            faults: None,
            degrade: None,
        }
    }

    /// Pace wall-mode service to the simulated device latency.
    pub fn realtime(mut self, yes: bool) -> Scheduler {
        self.realtime = yes;
        self
    }

    /// Attach a fault-injection plan. Workers gain the
    /// Up/Degraded/Down health machine, crashed workers' in-flight
    /// frames are re-dispatched under the plan's [`RecoveryConfig`], and
    /// the report grows a [`FaultSummary`]. Virtual clock only —
    /// [`Scheduler::run_wall`] rejects a plan.
    pub fn faults(mut self, plan: FaultPlan) -> Scheduler {
        self.faults = Some(plan);
        self
    }

    /// Attach a precision-degradation ladder: sustained SLA misses step
    /// service down the rungs (and headroom steps back up) under the
    /// same hysteresis rule as [`super::AdaptivePrecision`]. Rung 0 must
    /// be the full-precision design (scale 1.0); scales must be
    /// positive.
    pub fn degrade(
        mut self,
        rungs: Vec<DegradeRung>,
        cfg: HysteresisConfig,
    ) -> anyhow::Result<Scheduler> {
        anyhow::ensure!(!rungs.is_empty(), "degrade ladder needs at least one rung");
        anyhow::ensure!(
            rungs.iter().all(|r| r.scale > 0.0 && r.scale.is_finite()),
            "degrade rung scales must be positive"
        );
        let controller = HysteresisController::new(rungs.len(), cfg)?;
        self.degrade = Some(DegradeLadder { rungs, controller });
        Ok(self)
    }

    fn deadline(cfg: &StreamConfig, emitted_at: f64) -> f64 {
        match cfg.sla_ms {
            Some(ms) => emitted_at + ms / 1e3,
            None => f64::INFINITY,
        }
    }

    fn is_violation(cfg: &StreamConfig, e2e_s: f64) -> bool {
        cfg.sla_ms.map(|ms| e2e_s > ms / 1e3).unwrap_or(false)
    }

    // -- virtual mode -------------------------------------------------------

    /// Deterministic discrete-event run over a [`VirtualClock`] ticking at
    /// `clock_mhz` (use the target device's clock so service latencies map
    /// 1:1 to `perf::cycles` units).
    ///
    /// With a [`FaultPlan`] attached the same event loop additionally
    /// replays the injection schedule: crashed workers drop out of
    /// dispatch, their in-flight frames re-dispatch under the retry
    /// budget, and timed-out / corrupted completions re-run — all on the
    /// virtual clock, so an injected run is exactly as byte-reproducible
    /// as a fault-free one.
    pub fn run_virtual(self, clock_mhz: u64) -> anyhow::Result<MultiServingReport> {
        self.run_virtual_traced(clock_mhz, None)
    }

    /// [`Scheduler::run_virtual`] with an optional [`TraceSink`]: every
    /// simulation event additionally records a typed trace event (frame
    /// emit/drop/dispatch/service/complete, fault inject/redispatch,
    /// retry/timeout/fail) stamped at the cycle the loop processed it.
    /// `None` is the plain run — one untaken branch per event, nothing
    /// allocated. Because the loop is single-threaded over a
    /// `(cycle, seq)`-ordered heap, the recorded trace is byte-identical
    /// across runs and host thread counts.
    pub fn run_virtual_traced(
        self,
        clock_mhz: u64,
        mut trace: Option<&mut TraceSink>,
    ) -> anyhow::Result<MultiServingReport> {
        let Scheduler {
            streams,
            sources,
            mut workers,
            mut policy,
            realtime: _,
            faults,
            degrade,
        } = self;
        let backend = workers[0].name();
        let policy_name = policy.name().to_string();
        let with_patches = workers.iter().any(|w| w.needs_patches());
        let clock = VirtualClock::new(clock_mhz);

        // Any attached plan — even an event-free one carrying only a
        // recovery config (e.g. a frame timeout) — gets a fault block in
        // the report; `None` keeps fault-free JSON byte-identical.
        let injecting = faults.is_some();
        let plan = faults.unwrap_or_default();
        let recovery = plan.recovery;
        let fault_events = plan.sorted_events();
        let mut ladder = degrade;

        let queues: Vec<BoundedQueue<Frame>> = streams
            .iter()
            .map(|c| BoundedQueue::new(c.queue_depth))
            .collect();
        let mut stats: Vec<StreamStats> = vec![StreamStats::default(); streams.len()];
        let n_workers = workers.len();
        let mut busy: Vec<bool> = vec![false; n_workers];
        let mut busy_s: Vec<f64> = vec![0.0; n_workers];
        let mut served: Vec<u64> = vec![0; n_workers];

        // Tracks are registered once up front so recording inside the
        // loop is an index, never a name lookup.
        let (stream_tracks, worker_tracks, ctrl) = match trace.as_deref_mut() {
            Some(sink) => (
                (0..streams.len())
                    .map(|s| sink.track(TrackKind::Stream, &format!("stream{s}")))
                    .collect::<Vec<_>>(),
                (0..n_workers)
                    .map(|w| sink.track(TrackKind::Worker, &format!("worker{w}")))
                    .collect::<Vec<_>>(),
                sink.track(TrackKind::Control, "faults"),
            ),
            None => (Vec::new(), Vec::new(), TrackId(0)),
        };

        // Fault-recovery state. All of it stays at its initial value on a
        // plan-free run, so the fault-free event sequence is untouched.
        let mut health: Vec<Health> = vec![Health::Up; n_workers];
        let mut slow_factor: Vec<f64> = vec![1.0; n_workers];
        let mut corrupt_next: Vec<bool> = vec![false; n_workers];
        let mut inflight: Vec<Option<InFlight>> = (0..n_workers).map(|_| None).collect();
        let mut dispatch_counter: u64 = 0;
        let mut retry_pool: VecDeque<Frame> = VecDeque::new();
        let mut tracker = DowntimeTracker::new(n_workers);
        let mut summary = FaultSummary::default();

        let mut heap: BinaryHeap<Event> = BinaryHeap::new();
        let mut seq: u64 = 0;
        // Fault events are seeded first (lowest seqs): at an equal cycle a
        // crash pops before the completions scheduled after it, so a
        // same-cycle finish on a crashing worker is lost — the
        // pessimistic, deterministic reading.
        for (index, ev) in fault_events.iter().enumerate() {
            heap.push(Event {
                cycle: clock.seconds_to_cycles(ev.at_s),
                seq,
                kind: EventKind::Fault { index },
            });
            seq += 1;
        }
        for (s, src) in sources.iter().enumerate() {
            if streams[s].frames > 0 {
                heap.push(Event {
                    cycle: clock.seconds_to_cycles(src.due_at(0)),
                    seq,
                    kind: EventKind::Arrival { stream: s, idx: 0 },
                });
                seq += 1;
            }
        }

        while let Some(ev) = heap.pop() {
            clock.advance_to(ev.cycle);
            match ev.kind {
                EventKind::Arrival { stream, idx } => {
                    let mut frame = if with_patches {
                        sources[stream].make_frame(idx)
                    } else {
                        sources[stream].make_stub(idx)
                    };
                    frame.stream = stream;
                    frame.emitted_at = clock.now();
                    let id = frame.id;
                    let outcome = queues[stream].push(frame);
                    if let Some(sink) = trace.as_deref_mut() {
                        sink.instant(
                            stream_tracks[stream],
                            "emit",
                            clock.cycles(),
                            vec![("frame", id.into())],
                        );
                        if outcome.dropped_oldest() {
                            // Drop-oldest evicts the head, not the
                            // arrival; the instant carries the arriving
                            // frame that forced it.
                            sink.instant(
                                stream_tracks[stream],
                                "drop",
                                clock.cycles(),
                                vec![("forced_by", id.into())],
                            );
                        }
                    }
                    if idx + 1 < streams[stream].frames {
                        heap.push(Event {
                            cycle: clock.seconds_to_cycles(sources[stream].due_at(idx + 1)),
                            seq,
                            kind: EventKind::Arrival {
                                stream,
                                idx: idx + 1,
                            },
                        });
                        seq += 1;
                    }
                }
                EventKind::Fault { index } => {
                    let fev = &fault_events[index];
                    let w = fev.unit;
                    if w < n_workers {
                        if let Some(sink) = trace.as_deref_mut() {
                            let name = match fev.kind {
                                FaultKind::Crash => "fault_crash",
                                FaultKind::Recover => "fault_recover",
                                FaultKind::SlowDown { .. } => "fault_slowdown",
                                FaultKind::SlowEnd => "fault_slow_end",
                                FaultKind::Corrupt => "fault_corrupt",
                            };
                            sink.instant(ctrl, name, clock.cycles(), vec![("worker", w.into())]);
                        }
                        match fev.kind {
                            FaultKind::Crash => {
                                if health[w] != Health::Down {
                                    health[w] = Health::Down;
                                    tracker.mark_down(w, clock.now());
                                    summary.injected_crashes += 1;
                                    busy[w] = false;
                                    // The pending Completion/Timeout events
                                    // for this dispatch become stale (the
                                    // dispatch id no longer matches).
                                    if let Some(fl) = inflight[w].take() {
                                        if let Some(sink) = trace.as_deref_mut() {
                                            // The crash truncates the
                                            // in-flight service span.
                                            sink.span(
                                                worker_tracks[w],
                                                "aborted",
                                                fl.started,
                                                clock.cycles() - fl.started,
                                                vec![
                                                    ("frame", fl.frame.id.into()),
                                                    ("stream", fl.frame.stream.into()),
                                                ],
                                            );
                                        }
                                        if !fl.abandoned {
                                            summary.redispatches += 1;
                                            schedule_retry(
                                                fl.frame, &recovery, &clock, &mut heap,
                                                &mut seq, &mut stats, &mut summary,
                                                trace.as_deref_mut().map(|s| (s, ctrl)),
                                            );
                                        }
                                    }
                                }
                            }
                            FaultKind::Recover => {
                                if health[w] == Health::Down {
                                    health[w] = if slow_factor[w] > 1.0 {
                                        Health::Degraded
                                    } else {
                                        Health::Up
                                    };
                                    tracker.mark_up(w, clock.now());
                                }
                            }
                            FaultKind::SlowDown { factor } => {
                                summary.injected_slowdowns += 1;
                                slow_factor[w] = factor.max(1.0);
                                if health[w] == Health::Up {
                                    health[w] = Health::Degraded;
                                }
                            }
                            FaultKind::SlowEnd => {
                                slow_factor[w] = 1.0;
                                if health[w] == Health::Degraded {
                                    health[w] = Health::Up;
                                }
                            }
                            FaultKind::Corrupt => {
                                summary.injected_corruptions += 1;
                                corrupt_next[w] = true;
                            }
                        }
                    }
                }
                EventKind::Completion {
                    worker,
                    dispatch,
                    device_s,
                } => {
                    let matches = inflight[worker]
                        .as_ref()
                        .map(|fl| fl.dispatch == dispatch)
                        .unwrap_or(false);
                    // A mismatch means the worker crashed under this
                    // dispatch (frame already re-dispatched): stale event.
                    if matches {
                        let fl = inflight[worker].take().expect("matched in-flight");
                        busy[worker] = false;
                        served[worker] += 1;
                        busy_s[worker] += device_s;
                        if let Some(sink) = trace.as_deref_mut() {
                            sink.service_span(
                                worker_tracks[worker],
                                "service",
                                fl.started,
                                clock.cycles() - fl.started,
                                vec![
                                    ("frame", fl.frame.id.into()),
                                    ("stream", fl.frame.stream.into()),
                                    ("rung", fl.rung.into()),
                                ],
                            );
                        }
                        if fl.corrupted {
                            summary.corrupted_frames += 1;
                            if let Some(sink) = trace.as_deref_mut() {
                                sink.instant(
                                    ctrl,
                                    "corrupt_detected",
                                    clock.cycles(),
                                    vec![("frame", fl.frame.id.into()), ("worker", worker.into())],
                                );
                            }
                            schedule_retry(
                                fl.frame, &recovery, &clock, &mut heap, &mut seq,
                                &mut stats, &mut summary,
                                trace.as_deref_mut().map(|s| (s, ctrl)),
                            );
                        } else if !fl.abandoned {
                            let e2e = clock.now() - fl.frame.emitted_at;
                            let stream = fl.frame.stream;
                            stats[stream].record(
                                e2e,
                                device_s,
                                Self::is_violation(&streams[stream], e2e),
                            );
                            if let Some(sink) = trace.as_deref_mut() {
                                sink.instant(
                                    stream_tracks[stream],
                                    "complete",
                                    clock.cycles(),
                                    vec![
                                        ("frame", fl.frame.id.into()),
                                        ("e2e_ms", (e2e * 1e3).into()),
                                    ],
                                );
                            }
                            if fl.rung > 0 {
                                summary.degraded_frames += 1;
                            }
                            if let Some(lad) = ladder.as_mut() {
                                let deadline = streams[stream]
                                    .sla_ms
                                    .map(|ms| ms / 1e3)
                                    .unwrap_or(f64::INFINITY);
                                lad.controller.observe(e2e, deadline);
                            }
                        }
                        // Abandoned dispatches already re-entered the
                        // retry path at their timeout.
                    }
                }
                EventKind::Timeout { worker, dispatch } => {
                    let frame = match inflight[worker].as_mut() {
                        Some(fl) if fl.dispatch == dispatch && !fl.abandoned => {
                            fl.abandoned = true;
                            Some(fl.frame.clone())
                        }
                        _ => None,
                    };
                    if let Some(frame) = frame {
                        summary.timeouts += 1;
                        if let Some(sink) = trace.as_deref_mut() {
                            sink.instant(
                                ctrl,
                                "timeout",
                                clock.cycles(),
                                vec![("frame", frame.id.into()), ("worker", worker.into())],
                            );
                        }
                        schedule_retry(
                            frame, &recovery, &clock, &mut heap, &mut seq, &mut stats,
                            &mut summary,
                            trace.as_deref_mut().map(|s| (s, ctrl)),
                        );
                    }
                }
                EventKind::Retry { frame } => {
                    if let Some(sink) = trace.as_deref_mut() {
                        sink.instant(
                            ctrl,
                            "retry",
                            clock.cycles(),
                            vec![("frame", frame.id.into()), ("stream", frame.stream.into())],
                        );
                    }
                    // Backoff elapsed: the frame re-enters contention ahead
                    // of the stream queues (it is the oldest work).
                    retry_pool.push_back(frame);
                }
            }

            // Pair waiting frames with idle (non-down) workers until one
            // side runs dry. Retried frames jump the queues, FIFO.
            loop {
                let idle: Vec<WorkerSnapshot> = busy
                    .iter()
                    .enumerate()
                    .filter(|(w, b)| !**b && health[*w] != Health::Down)
                    .map(|(w, _)| WorkerSnapshot {
                        worker: w,
                        busy_s: busy_s[w],
                        served: served[w],
                    })
                    .collect();
                if idle.is_empty() {
                    break;
                }
                let frame = if let Some(f) = retry_pool.pop_front() {
                    f
                } else {
                    let ready: Vec<StreamSnapshot> = queues
                        .iter()
                        .enumerate()
                        .filter_map(|(s, q)| {
                            // NB: `len()` takes the queue lock, so it must
                            // be read before entering the `peek_front`
                            // closure (which holds that same non-reentrant
                            // lock).
                            let queued = q.len();
                            q.peek_front(|f| StreamSnapshot {
                                stream: s,
                                queued,
                                head_emitted_at: f.emitted_at,
                                head_deadline: Self::deadline(&streams[s], f.emitted_at),
                            })
                        })
                        .collect();
                    if ready.is_empty() {
                        break;
                    }
                    let s = ready[policy.pick_stream(&ready)].stream;
                    queues[s].try_pop().expect("ready stream has a frame")
                };
                let w = idle[policy.pick_worker(&idle)].worker;
                let base_s = workers[w].service(&frame)?;
                let rung = ladder
                    .as_ref()
                    .map(|l| l.controller.current())
                    .unwrap_or(0);
                let scale = ladder.as_ref().map(|l| l.rungs[rung].scale).unwrap_or(1.0);
                let device_s = base_s * scale * slow_factor[w];
                let service_cycles = clock.seconds_to_cycles(device_s).max(1);
                busy[w] = true;
                dispatch_counter += 1;
                let corrupted = std::mem::take(&mut corrupt_next[w]);
                if let Some(sink) = trace.as_deref_mut() {
                    let emit_cycle = clock.seconds_to_cycles(frame.emitted_at);
                    sink.instant(
                        worker_tracks[w],
                        "dispatch",
                        clock.cycles(),
                        vec![
                            ("frame", frame.id.into()),
                            ("stream", frame.stream.into()),
                            ("wait_cycles", clock.cycles().saturating_sub(emit_cycle).into()),
                        ],
                    );
                }
                inflight[w] = Some(InFlight {
                    frame,
                    dispatch: dispatch_counter,
                    corrupted,
                    abandoned: false,
                    rung,
                    started: clock.cycles(),
                });
                heap.push(Event {
                    cycle: clock.cycles() + service_cycles,
                    seq,
                    kind: EventKind::Completion {
                        worker: w,
                        dispatch: dispatch_counter,
                        device_s,
                    },
                });
                seq += 1;
                if let Some(timeout_s) = recovery.frame_timeout_s {
                    let timeout_cycles = clock.seconds_to_cycles(timeout_s).max(1);
                    if timeout_cycles < service_cycles {
                        heap.push(Event {
                            cycle: clock.cycles() + timeout_cycles,
                            seq,
                            kind: EventKind::Timeout {
                                worker: w,
                                dispatch: dispatch_counter,
                            },
                        });
                        seq += 1;
                    }
                }
            }
        }

        // Conservation drain: with every capable worker down and no
        // recovery left in the schedule, frames strand in the queues and
        // the retry pool — they are `failed`, never silently lost.
        while let Some(f) = retry_pool.pop_front() {
            if let Some(sink) = trace.as_deref_mut() {
                sink.instant(
                    ctrl,
                    "fail",
                    clock.cycles(),
                    vec![("frame", f.id.into()), ("stream", f.stream.into())],
                );
            }
            stats[f.stream].failed += 1;
        }
        for q in &queues {
            while let Some(f) = q.try_pop() {
                if let Some(sink) = trace.as_deref_mut() {
                    sink.instant(
                        ctrl,
                        "fail",
                        clock.cycles(),
                        vec![("frame", f.id.into()), ("stream", f.stream.into())],
                    );
                }
                stats[f.stream].failed += 1;
            }
        }
        for (s, q) in queues.iter().enumerate() {
            stats[s].offered = q.pushed();
            stats[s].dropped = q.dropped();
            debug_assert_eq!(
                q.pushed(),
                q.popped() + q.dropped(),
                "virtual run must drain every queue"
            );
        }
        let elapsed = clock.now();
        tracker.finish(elapsed);
        let fault_block = if injecting || ladder.is_some() {
            summary.availability = tracker.availability(elapsed);
            summary.mttr_s = tracker.mttr_s();
            if let Some(lad) = &ladder {
                summary.precision_switches = lad.controller.switches().to_vec();
                summary.final_rung = lad.controller.current();
            }
            Some(summary)
        } else {
            None
        };
        let worker_names: Vec<String> = workers.iter().map(|w| w.name()).collect();
        Ok(build_report(
            backend,
            policy_name,
            "virtual",
            &streams,
            stats,
            worker_names,
            served,
            busy_s,
            elapsed,
            fault_block,
        ))
    }

    // -- wall mode ----------------------------------------------------------

    /// Threaded real-time run: one producer thread per stream, one worker
    /// thread per pool slot. Free workers pull work themselves, so the
    /// policy governs *stream* selection; worker selection is whichever
    /// thread frees up first.
    pub fn run_wall(self) -> anyhow::Result<MultiServingReport> {
        let Scheduler {
            streams,
            sources,
            workers,
            policy,
            realtime,
            faults,
            degrade,
        } = self;
        if faults.is_some() || degrade.is_some() {
            anyhow::bail!(
                "fault injection and precision degradation require the \
                 deterministic virtual clock — use run_virtual()"
            );
        }
        let backend = workers[0].name();
        let policy_name = policy.name().to_string();
        // Collected before the models move into their threads.
        let worker_names: Vec<String> = workers.iter().map(|w| w.name()).collect();
        let n_workers = workers.len();
        let clock = WallClock::new();

        let queues: Vec<BoundedQueue<Frame>> = streams
            .iter()
            .map(|c| BoundedQueue::new(c.queue_depth))
            .collect();
        let stats: Mutex<Vec<StreamStats>> =
            Mutex::new(vec![StreamStats::default(); streams.len()]);
        // (served, busy seconds) per worker.
        let worker_acc: Mutex<Vec<(u64, f64)>> = Mutex::new(vec![(0, 0.0); n_workers]);
        let policy = Mutex::new(policy);
        let error: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        // Workers sleep here when every queue is empty; producers ring it
        // after each push/close (and error-exiting workers, so siblings
        // wake up and notice the failure).
        let bell = (Mutex::new(()), Condvar::new());

        std::thread::scope(|scope| {
            let streams = &streams;
            let queues = &queues;
            let clock = &clock;
            let bell = &bell;
            let stats = &stats;
            let worker_acc = &worker_acc;
            let policy = &policy;
            let error = &error;

            for (i, mut source) in sources.into_iter().enumerate() {
                let frames = streams[i].frames;
                scope.spawn(move || {
                    for _ in 0..frames {
                        let frame = source.next_frame(clock);
                        queues[i].push(frame);
                        let _g = bell.0.lock().unwrap();
                        bell.1.notify_all();
                    }
                    queues[i].close();
                    let _g = bell.0.lock().unwrap();
                    bell.1.notify_all();
                });
            }

            for (wi, mut model) in workers.into_iter().enumerate() {
                scope.spawn(move || {
                    loop {
                        // Select a stream under the bell lock (serializes
                        // worker decisions, so pick + pop is atomic with
                        // respect to other workers).
                        let frame = {
                            let mut guard = bell.0.lock().unwrap();
                            loop {
                                let ready: Vec<StreamSnapshot> = queues
                                    .iter()
                                    .enumerate()
                                    .filter_map(|(s, q)| {
                                        // len() before peek_front: both
                                        // take the same non-reentrant
                                        // queue lock.
                                        let queued = q.len();
                                        q.peek_front(|f| StreamSnapshot {
                                            stream: s,
                                            queued,
                                            head_emitted_at: f.emitted_at,
                                            head_deadline: Self::deadline(
                                                &streams[s],
                                                f.emitted_at,
                                            ),
                                        })
                                    })
                                    .collect();
                                if !ready.is_empty() {
                                    let pos = policy.lock().unwrap().pick_stream(&ready);
                                    if let Some(frame) = queues[ready[pos].stream].try_pop() {
                                        break frame;
                                    }
                                    continue; // raced a drop-oldest eviction
                                }
                                if error.lock().unwrap().is_some()
                                    || queues.iter().all(|q| q.is_closed() && q.is_empty())
                                {
                                    return;
                                }
                                guard = bell.1.wait(guard).unwrap();
                            }
                        };
                        let t0 = clock.now();
                        match model.service(&frame) {
                            Ok(device_s) => {
                                if realtime && device_s > 0.0 {
                                    std::thread::sleep(Duration::from_secs_f64(device_s));
                                }
                                let done = clock.now();
                                let e2e = done - frame.emitted_at;
                                stats.lock().unwrap()[frame.stream].record(
                                    e2e,
                                    device_s,
                                    Self::is_violation(&streams[frame.stream], e2e),
                                );
                                let mut acc = worker_acc.lock().unwrap();
                                acc[wi].0 += 1;
                                acc[wi].1 += done - t0;
                            }
                            Err(e) => {
                                *error.lock().unwrap() = Some(e);
                                let _g = bell.0.lock().unwrap();
                                bell.1.notify_all();
                                return;
                            }
                        }
                    }
                });
            }
        });

        if let Some(e) = error.into_inner().unwrap() {
            return Err(e);
        }
        let mut stats = stats.into_inner().unwrap();
        for (s, q) in queues.iter().enumerate() {
            stats[s].offered = q.pushed();
            stats[s].dropped = q.dropped();
        }
        let elapsed = clock.now();
        let acc = worker_acc.into_inner().unwrap();
        let served: Vec<u64> = acc.iter().map(|(n, _)| *n).collect();
        let busy_s: Vec<f64> = acc.iter().map(|(_, b)| *b).collect();
        Ok(build_report(
            backend,
            policy_name,
            "wall",
            &streams,
            stats,
            worker_names,
            served,
            busy_s,
            elapsed,
            None,
        ))
    }
}

// ---------------------------------------------------------------------------
// Event queue (virtual mode) and report assembly.
// ---------------------------------------------------------------------------

struct Event {
    cycle: Cycles,
    seq: u64,
    kind: EventKind,
}

enum EventKind {
    Arrival {
        stream: usize,
        idx: u64,
    },
    Completion {
        worker: usize,
        /// Dispatch id this completion belongs to — a crash bumps the
        /// worker past it, turning the event into a deterministic no-op.
        dispatch: u64,
        device_s: f64,
    },
    /// Injected fault (index into the plan's sorted event list).
    Fault {
        index: usize,
    },
    /// A re-dispatched frame re-enters contention after its backoff.
    Retry {
        frame: Frame,
    },
    /// Per-frame watchdog for one dispatch on one worker.
    Timeout {
        worker: usize,
        dispatch: u64,
    },
}

/// What a busy worker currently holds (virtual mode, fault path).
struct InFlight {
    frame: Frame,
    /// Monotonic dispatch id — Completion/Timeout events carrying an
    /// older id (pre-crash) no longer match and are dropped.
    dispatch: u64,
    /// An armed corruption fired on this dispatch: the result is
    /// discarded and the frame re-dispatched.
    corrupted: bool,
    /// The watchdog fired: the frame already re-entered the retry path,
    /// so the eventual completion only frees the worker.
    abandoned: bool,
    /// Degrade-ladder rung the frame was served at (0 = full precision).
    rung: usize,
    /// Cycle the dispatch started — the service-span anchor when
    /// tracing; unused (always stamped) otherwise.
    started: Cycles,
}

/// Re-dispatch `frame` after exponential backoff, or account it as
/// failed once the retry budget is spent. Never silently drops a frame.
#[allow(clippy::too_many_arguments)]
fn schedule_retry(
    mut frame: Frame,
    recovery: &RecoveryConfig,
    clock: &VirtualClock,
    heap: &mut BinaryHeap<Event>,
    seq: &mut u64,
    stats: &mut [StreamStats],
    summary: &mut FaultSummary,
    trace: Option<(&mut TraceSink, TrackId)>,
) {
    frame.attempts += 1;
    if frame.attempts > recovery.max_retries {
        if let Some((sink, ctrl)) = trace {
            sink.instant(
                ctrl,
                "fail",
                clock.cycles(),
                vec![("frame", frame.id.into()), ("stream", frame.stream.into())],
            );
        }
        stats[frame.stream].failed += 1;
        return;
    }
    summary.retries += 1;
    let shift = (frame.attempts - 1).min(20);
    let backoff_s = recovery.backoff_base_s * f64::from(1u32 << shift);
    heap.push(Event {
        cycle: clock.cycles() + clock.seconds_to_cycles(backoff_s).max(1),
        seq: *seq,
        kind: EventKind::Retry { frame },
    });
    *seq += 1;
}

impl PartialEq for Event {
    fn eq(&self, other: &Event) -> bool {
        self.cycle == other.cycle && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Event) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Event) -> std::cmp::Ordering {
        // Reversed so `BinaryHeap` (a max-heap) pops the earliest
        // (cycle, seq) first — a deterministic total order.
        (other.cycle, other.seq).cmp(&(self.cycle, self.seq))
    }
}

#[allow(clippy::too_many_arguments)]
fn build_report(
    backend: String,
    policy: String,
    clock: &str,
    streams: &[StreamConfig],
    stats: Vec<StreamStats>,
    worker_names: Vec<String>,
    served: Vec<u64>,
    busy_s: Vec<f64>,
    elapsed: f64,
    faults: Option<FaultSummary>,
) -> MultiServingReport {
    let mut all_e2e: Vec<f64> = Vec::new();
    let mut all_device: Vec<f64> = Vec::new();
    let (mut offered, mut completed, mut dropped, mut violations) = (0u64, 0u64, 0u64, 0u64);
    let mut failed = 0u64;
    let stream_reports: Vec<StreamReport> = streams
        .iter()
        .zip(stats.iter())
        .enumerate()
        .map(|(i, (cfg, st))| {
            offered += st.offered;
            completed += st.completed();
            dropped += st.dropped;
            failed += st.failed;
            violations += st.sla_violations;
            all_e2e.extend_from_slice(&st.e2e);
            all_device.extend_from_slice(&st.device);
            StreamReport::from_stats(i, cfg.offered_fps, cfg.sla_ms, st)
        })
        .collect();
    let worker_reports: Vec<WorkerReport> = worker_names
        .into_iter()
        .enumerate()
        .map(|(w, name)| WorkerReport {
            worker: w,
            name,
            served: served[w],
            busy_seconds: busy_s[w],
            utilization: if elapsed > 0.0 {
                busy_s[w] / elapsed
            } else {
                0.0
            },
        })
        .collect();
    MultiServingReport {
        backend,
        policy,
        clock: clock.to_string(),
        elapsed_seconds: elapsed,
        aggregate: AggregateReport {
            offered,
            completed,
            dropped,
            failed,
            drop_rate: dropped as f64 / offered.max(1) as f64,
            sla_violations: violations,
            achieved_fps: if elapsed > 0.0 {
                completed as f64 / elapsed
            } else {
                0.0
            },
            e2e_latency: Summary::from(&all_e2e),
            device_latency: Summary::from(&all_device),
        },
        streams: stream_reports,
        workers: worker_reports,
        faults,
    }
}
