//! Adaptive precision scaling — an extension beyond the paper.
//!
//! VAQF compiles one accelerator per frame-rate target. The paper's §3
//! notes that "if there exist multiple frame rate targets, all the
//! possible precisions can be evaluated"; this module takes the next step
//! the conclusion gestures at ("generalized to other frame rate targets"):
//! keep the precision *ladder* resident and switch at runtime based on the
//! observed service rate — degrade to fewer activation bits when the
//! current variant cannot sustain the offered rate (e.g. thermal
//! throttling, co-tenants, higher-resolution input), climb back up when
//! there is headroom. Accuracy is sacrificed exactly when — and only when
//! — the real-time contract would otherwise break, mirroring the
//! compile-time trade-off at serve time.
//!
//! The windowed decision rule lives in [`HysteresisController`] so the
//! multi-stream scheduler's fault-driven degradation
//! ([`Scheduler::degrade`]) applies the identical hysteresis to analytic
//! service-time scaling.
//!
//! [`Scheduler::degrade`]: super::Scheduler::degrade

use crate::api::VaqfError;
use crate::runtime::InferenceBackend;

/// Tunable knobs of the windowed hysteresis rule (see
/// [`HysteresisController`]); defaults match the controller's original
/// hardcoded behaviour.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HysteresisConfig {
    /// Observations per decision window.
    pub window_len: usize,
    /// Demote when ≥ this fraction of a window missed its deadline.
    pub down_frac: f64,
    /// Promote when *every* observation in a window finished below
    /// `up_margin · deadline`.
    pub up_margin: f64,
}

impl Default for HysteresisConfig {
    fn default() -> HysteresisConfig {
        HysteresisConfig {
            window_len: 8,
            down_frac: 0.5,
            up_margin: 0.5,
        }
    }
}

impl HysteresisConfig {
    /// Reject degenerate configurations (zero-length windows, fractions
    /// outside `(0, 1]`) with a matchable [`VaqfError::Config`].
    pub fn validate(&self) -> Result<(), VaqfError> {
        if self.window_len == 0 {
            return Err(VaqfError::config("hysteresis window_len must be ≥ 1"));
        }
        if !(self.down_frac > 0.0 && self.down_frac <= 1.0) {
            return Err(VaqfError::config("hysteresis down_frac must be in (0, 1]"));
        }
        if !(self.up_margin > 0.0 && self.up_margin <= 1.0) {
            return Err(VaqfError::config("hysteresis up_margin must be in (0, 1]"));
        }
        Ok(())
    }
}

/// Hysteresis rule over an abstract rung index `0..rungs` (0 = highest
/// precision). Watches a sliding window of (latency, deadline)
/// observations:
///
/// * sustained misses (latency > deadline on ≥ `down_frac` of the
///   window) ⇒ step down (lower precision, faster variant);
/// * sustained headroom (latency < `up_margin`·deadline on the whole
///   window) ⇒ step up (higher precision, better accuracy).
///
/// Both windows are cleared at every decision boundary, so consecutive
/// switches are ≥ `window_len` observations apart — the controller
/// cannot demote→promote→demote within one window on any input.
#[derive(Debug, Clone)]
pub struct HysteresisController {
    cfg: HysteresisConfig,
    rungs: usize,
    current: usize,
    window: Vec<bool>, // true = missed deadline
    headroom: Vec<bool>,
    switches: Vec<(u64, usize)>,
    seen: u64,
}

impl HysteresisController {
    pub fn new(rungs: usize, cfg: HysteresisConfig) -> Result<HysteresisController, VaqfError> {
        if rungs == 0 {
            return Err(VaqfError::config(
                "hysteresis controller needs at least one rung",
            ));
        }
        cfg.validate()?;
        Ok(HysteresisController {
            cfg,
            rungs,
            current: 0,
            window: Vec::new(),
            headroom: Vec::new(),
            switches: Vec::new(),
            seen: 0,
        })
    }

    pub fn current(&self) -> usize {
        self.current
    }

    pub fn config(&self) -> HysteresisConfig {
        self.cfg
    }

    /// Observations consumed so far.
    pub fn observations(&self) -> u64 {
        self.seen
    }

    /// `(observation-count, new-rung)` per switch, in order.
    pub fn switches(&self) -> &[(u64, usize)] {
        &self.switches
    }

    /// Jump to a rung, discarding the partial window (test scaffolding
    /// and explicit operator overrides).
    pub fn reset_to(&mut self, rung: usize) {
        assert!(rung < self.rungs, "rung out of range");
        self.current = rung;
        self.window.clear();
        self.headroom.clear();
    }

    /// Feed one (latency, deadline) observation; returns `Some(rung)`
    /// when this observation closed a window and moved the ladder.
    pub fn observe(&mut self, latency_s: f64, deadline_s: f64) -> Option<usize> {
        self.seen += 1;
        self.window.push(latency_s > deadline_s);
        self.headroom
            .push(latency_s < deadline_s * self.cfg.up_margin);
        if self.window.len() < self.cfg.window_len {
            return None;
        }
        let misses = self.window.iter().filter(|&&m| m).count() as f64;
        let mut switched = None;
        if misses / self.window.len() as f64 >= self.cfg.down_frac
            && self.current + 1 < self.rungs
        {
            self.current += 1;
            self.switches.push((self.seen, self.current));
            switched = Some(self.current);
        } else if self.headroom.iter().all(|&h| h) && self.current > 0 {
            self.current -= 1;
            self.switches.push((self.seen, self.current));
            switched = Some(self.current);
        }
        self.window.clear();
        self.headroom.clear();
        switched
    }
}

/// Hysteresis controller over a precision ladder of inference backends.
/// Ladder entries are ordered highest-precision-first; the decision rule
/// is [`HysteresisController`].
pub struct AdaptivePrecision {
    /// (label, backend), highest precision first.
    ladder: Vec<(String, Box<dyn InferenceBackend>)>,
    controller: HysteresisController,
}

/// Configures an [`AdaptivePrecision`] before the first frame; obtained
/// from [`AdaptivePrecision::builder`].
pub struct AdaptivePrecisionBuilder {
    ladder: Vec<(String, Box<dyn InferenceBackend>)>,
    cfg: HysteresisConfig,
}

impl AdaptivePrecisionBuilder {
    pub fn window_len(mut self, n: usize) -> Self {
        self.cfg.window_len = n;
        self
    }

    pub fn down_frac(mut self, f: f64) -> Self {
        self.cfg.down_frac = f;
        self
    }

    pub fn up_margin(mut self, f: f64) -> Self {
        self.cfg.up_margin = f;
        self
    }

    pub fn hysteresis(mut self, cfg: HysteresisConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Validate and build; an empty ladder or a degenerate hysteresis
    /// configuration is a [`VaqfError::Config`], not a panic.
    pub fn build(self) -> Result<AdaptivePrecision, VaqfError> {
        if self.ladder.is_empty() {
            return Err(VaqfError::config(
                "adaptive precision needs a non-empty ladder",
            ));
        }
        let controller = HysteresisController::new(self.ladder.len(), self.cfg)?;
        Ok(AdaptivePrecision {
            ladder: self.ladder,
            controller,
        })
    }
}

impl AdaptivePrecision {
    /// Build with the default hysteresis ([`HysteresisConfig`]).
    pub fn new(
        ladder: Vec<(String, Box<dyn InferenceBackend>)>,
    ) -> Result<AdaptivePrecision, VaqfError> {
        AdaptivePrecision::builder(ladder).build()
    }

    /// Start configuring the controller's window/threshold knobs.
    pub fn builder(ladder: Vec<(String, Box<dyn InferenceBackend>)>) -> AdaptivePrecisionBuilder {
        AdaptivePrecisionBuilder {
            ladder,
            cfg: HysteresisConfig::default(),
        }
    }

    /// Build a ladder of simulated-FPGA rungs from a compile session: one
    /// design per precision in `bits` (highest precision first, per the
    /// ladder convention), each wired as a [`SimBackend`] with weights
    /// generated from `seed`. All rungs are compiled through the
    /// session's shared design-space-search context, so the per-precision
    /// searches overlap with — and are served from — whatever the session
    /// has already compiled.
    ///
    /// [`SimBackend`]: crate::runtime::SimBackend
    pub fn from_session(
        session: &crate::api::Session,
        bits: &[u8],
        seed: u64,
    ) -> Result<AdaptivePrecision, VaqfError> {
        let mut ladder: Vec<(String, Box<dyn InferenceBackend>)> = Vec::with_capacity(bits.len());
        for &b in bits {
            let design = session.compile_for_bits(Some(b))?;
            let backend = crate::runtime::SimBackend {
                executor: design.simulator_with_seed(seed),
                realtime: false,
            };
            ladder.push((design.summary().label.clone(), Box::new(backend)));
        }
        AdaptivePrecision::new(ladder)
    }

    pub fn current_label(&self) -> &str {
        &self.ladder[self.controller.current()].0
    }

    pub fn current_index(&self) -> usize {
        self.controller.current()
    }

    /// `(frames-seen, new-rung)` per switch, in order.
    pub fn switches(&self) -> &[(u64, usize)] {
        self.controller.switches()
    }

    /// Jump to a rung, discarding the partial window.
    pub fn reset_to(&mut self, rung: usize) {
        self.controller.reset_to(rung);
    }

    /// Run one frame under a deadline; returns (logits, device seconds,
    /// ladder index used).
    pub fn infer(
        &mut self,
        patches: &[f32],
        deadline_s: f64,
    ) -> anyhow::Result<(Vec<f32>, f64, usize)> {
        let used = self.controller.current();
        let (logits, device_s) = self.ladder[used].1.infer(patches)?;
        self.controller.observe(device_s, deadline_s);
        Ok((logits, device_s, used))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Backend with a scriptable latency.
    struct FakeBackend {
        latency_s: f64,
    }

    impl InferenceBackend for FakeBackend {
        fn name(&self) -> String {
            format!("fake@{}", self.latency_s)
        }
        fn infer(&mut self, _patches: &[f32]) -> anyhow::Result<(Vec<f32>, f64)> {
            Ok((vec![0.0; 4], self.latency_s))
        }
    }

    fn ladder(lat_hi: f64, lat_lo: f64) -> AdaptivePrecision {
        AdaptivePrecision::new(vec![
            ("W1A8".into(), Box::new(FakeBackend { latency_s: lat_hi })),
            ("W1A4".into(), Box::new(FakeBackend { latency_s: lat_lo })),
        ])
        .unwrap()
    }

    #[test]
    fn starts_at_highest_precision() {
        let ap = ladder(0.01, 0.001);
        assert_eq!(ap.current_label(), "W1A8");
    }

    #[test]
    fn from_session_builds_sim_rungs_and_warms_the_ctx() {
        let session = crate::api::TargetSpec::new()
            .model(crate::model::micro())
            .device_preset("zcu102")
            .target_fps(100.0)
            .session()
            .unwrap();
        let mut ap = AdaptivePrecision::from_session(&session, &[8, 4], 7).unwrap();
        assert_eq!(ap.current_label(), "W1A8");
        ap.reset_to(1);
        assert_eq!(ap.current_label(), "W1A4");
        // Re-compiling either rung through the same session is a pure
        // memo hit — the ladder and the session share one SearchCtx.
        let before = session.search_ctx().stats();
        session.compile_for_bits(Some(4)).unwrap();
        let after = session.search_ctx().stats();
        assert_eq!(after.design_hits, before.design_hits + 1);
        assert_eq!(after.point_evals, before.point_evals);
    }

    #[test]
    fn empty_ladder_is_a_config_error_not_a_panic() {
        let err = AdaptivePrecision::new(Vec::new()).unwrap_err();
        assert!(matches!(err, VaqfError::Config { .. }), "{err}");
    }

    #[test]
    fn builder_rejects_degenerate_knobs() {
        fn two_rungs() -> Vec<(String, Box<dyn InferenceBackend>)> {
            vec![
                ("a".into(), Box::new(FakeBackend { latency_s: 0.01 }) as Box<_>),
                ("b".into(), Box::new(FakeBackend { latency_s: 0.001 }) as Box<_>),
            ]
        }
        for build in [
            AdaptivePrecision::builder(two_rungs()).window_len(0).build(),
            AdaptivePrecision::builder(two_rungs()).down_frac(0.0).build(),
            AdaptivePrecision::builder(two_rungs()).down_frac(1.5).build(),
            AdaptivePrecision::builder(two_rungs()).up_margin(-0.1).build(),
        ] {
            assert!(matches!(build.unwrap_err(), VaqfError::Config { .. }));
        }
    }

    #[test]
    fn builder_knobs_change_the_decision_window() {
        // window_len 4 demotes after 4 misses, not the default 8.
        let mut ap = AdaptivePrecision::builder(vec![
            ("hi".into(), Box::new(FakeBackend { latency_s: 0.010 }) as Box<_>),
            ("lo".into(), Box::new(FakeBackend { latency_s: 0.001 }) as Box<_>),
        ])
        .window_len(4)
        .build()
        .unwrap();
        for _ in 0..4 {
            ap.infer(&[0.0], 0.005).unwrap();
        }
        assert_eq!(ap.current_label(), "lo", "switches: {:?}", ap.switches());
    }

    #[test]
    fn steps_down_under_sustained_misses() {
        // Deadline 5 ms, W1A8 takes 10 ms ⇒ misses ⇒ must degrade.
        let mut ap = ladder(0.010, 0.001);
        for _ in 0..8 {
            ap.infer(&[0.0], 0.005).unwrap();
        }
        assert_eq!(ap.current_label(), "W1A4", "switches: {:?}", ap.switches());
    }

    #[test]
    fn steps_back_up_with_headroom() {
        let mut ap = ladder(0.002, 0.001);
        // Force down first.
        ap.reset_to(1);
        for _ in 0..8 {
            ap.infer(&[0.0], 0.005).unwrap(); // 1 ms ≪ 0.5·5 ms ⇒ headroom
        }
        assert_eq!(ap.current_label(), "W1A8");
    }

    #[test]
    fn stays_put_in_the_comfortable_band() {
        // 4 ms against a 5 ms deadline: no miss, but no 2× headroom either.
        let mut ap = ladder(0.004, 0.001);
        for _ in 0..32 {
            ap.infer(&[0.0], 0.005).unwrap();
        }
        assert_eq!(ap.current_label(), "W1A8");
        assert!(ap.switches().is_empty());
    }

    #[test]
    fn never_steps_below_ladder_bottom() {
        let mut ap = ladder(0.010, 0.009);
        for _ in 0..64 {
            ap.infer(&[0.0], 0.001).unwrap(); // everything misses
        }
        assert_eq!(ap.current_index(), 1, "must clamp at the bottom");
    }

    #[test]
    fn oscillation_is_damped_by_windowing() {
        // Alternating hit/miss at exactly the threshold should not flap
        // every frame: switches only happen at window boundaries.
        let mut ap = ladder(0.006, 0.001);
        for i in 0..32 {
            let deadline = if i % 2 == 0 { 0.004 } else { 0.1 };
            ap.infer(&[0.0], deadline).unwrap();
        }
        assert!(
            ap.switches().len() <= 32 / 8,
            "at most one switch per window: {:?}",
            ap.switches()
        );
    }

    #[test]
    fn bare_controller_reports_switch_points() {
        let mut c = HysteresisController::new(3, HysteresisConfig::default()).unwrap();
        for _ in 0..8 {
            c.observe(0.010, 0.005); // all miss ⇒ demote at the boundary
        }
        assert_eq!(c.current(), 1);
        assert_eq!(c.switches(), &[(8, 1)]);
        for _ in 0..8 {
            c.observe(0.001, 0.005); // deep headroom ⇒ promote
        }
        assert_eq!(c.current(), 0);
        assert_eq!(c.switches(), &[(8, 1), (16, 0)]);
    }
}
