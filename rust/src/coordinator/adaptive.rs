//! Adaptive precision scaling — an extension beyond the paper.
//!
//! VAQF compiles one accelerator per frame-rate target. The paper's §3
//! notes that "if there exist multiple frame rate targets, all the
//! possible precisions can be evaluated"; this module takes the next step
//! the conclusion gestures at ("generalized to other frame rate targets"):
//! keep the precision *ladder* resident and switch at runtime based on the
//! observed service rate — degrade to fewer activation bits when the
//! current variant cannot sustain the offered rate (e.g. thermal
//! throttling, co-tenants, higher-resolution input), climb back up when
//! there is headroom. Accuracy is sacrificed exactly when — and only when
//! — the real-time contract would otherwise break, mirroring the
//! compile-time trade-off at serve time.

use crate::runtime::InferenceBackend;

/// Hysteresis controller over a precision ladder.
///
/// Ladder entries are ordered highest-precision-first. The controller
/// watches a sliding window of (device-latency, deadline) observations:
///
/// * sustained misses (latency > deadline on ≥ `down_frac` of the window)
///   ⇒ step down (lower precision, faster variant);
/// * sustained headroom (latency < `up_margin`·deadline on the whole
///   window) ⇒ step up (higher precision, better accuracy).
pub struct AdaptivePrecision {
    /// (label, backend), highest precision first.
    ladder: Vec<(String, Box<dyn InferenceBackend>)>,
    current: usize,
    window: Vec<bool>, // true = missed deadline
    headroom: Vec<bool>,
    window_len: usize,
    down_frac: f64,
    up_margin: f64,
    pub switches: Vec<(u64, usize)>,
    frames_seen: u64,
}

impl AdaptivePrecision {
    pub fn new(ladder: Vec<(String, Box<dyn InferenceBackend>)>) -> AdaptivePrecision {
        assert!(!ladder.is_empty());
        AdaptivePrecision {
            ladder,
            current: 0,
            window: Vec::new(),
            headroom: Vec::new(),
            window_len: 8,
            down_frac: 0.5,
            up_margin: 0.5,
            switches: Vec::new(),
            frames_seen: 0,
        }
    }

    pub fn current_label(&self) -> &str {
        &self.ladder[self.current].0
    }

    pub fn current_index(&self) -> usize {
        self.current
    }

    /// Run one frame under a deadline; returns (logits, device seconds,
    /// ladder index used).
    pub fn infer(
        &mut self,
        patches: &[f32],
        deadline_s: f64,
    ) -> anyhow::Result<(Vec<f32>, f64, usize)> {
        let used = self.current;
        let (logits, device_s) = self.ladder[used].1.infer(patches)?;
        self.frames_seen += 1;
        self.observe(device_s, deadline_s);
        Ok((logits, device_s, used))
    }

    fn observe(&mut self, device_s: f64, deadline_s: f64) {
        self.window.push(device_s > deadline_s);
        self.headroom.push(device_s < deadline_s * self.up_margin);
        if self.window.len() < self.window_len {
            return;
        }
        let misses = self.window.iter().filter(|&&m| m).count() as f64;
        if misses / self.window.len() as f64 >= self.down_frac
            && self.current + 1 < self.ladder.len()
        {
            self.current += 1;
            self.switches.push((self.frames_seen, self.current));
        } else if self.headroom.iter().all(|&h| h) && self.current > 0 {
            self.current -= 1;
            self.switches.push((self.frames_seen, self.current));
        }
        self.window.clear();
        self.headroom.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Backend with a scriptable latency.
    struct FakeBackend {
        latency_s: f64,
    }

    impl InferenceBackend for FakeBackend {
        fn name(&self) -> String {
            format!("fake@{}", self.latency_s)
        }
        fn infer(&mut self, _patches: &[f32]) -> anyhow::Result<(Vec<f32>, f64)> {
            Ok((vec![0.0; 4], self.latency_s))
        }
    }

    fn ladder(lat_hi: f64, lat_lo: f64) -> AdaptivePrecision {
        AdaptivePrecision::new(vec![
            ("W1A8".into(), Box::new(FakeBackend { latency_s: lat_hi })),
            ("W1A4".into(), Box::new(FakeBackend { latency_s: lat_lo })),
        ])
    }

    #[test]
    fn starts_at_highest_precision() {
        let ap = ladder(0.01, 0.001);
        assert_eq!(ap.current_label(), "W1A8");
    }

    #[test]
    fn steps_down_under_sustained_misses() {
        // Deadline 5 ms, W1A8 takes 10 ms ⇒ misses ⇒ must degrade.
        let mut ap = ladder(0.010, 0.001);
        for _ in 0..8 {
            ap.infer(&[0.0], 0.005).unwrap();
        }
        assert_eq!(ap.current_label(), "W1A4", "switches: {:?}", ap.switches);
    }

    #[test]
    fn steps_back_up_with_headroom() {
        let mut ap = ladder(0.002, 0.001);
        // Force down first.
        ap.current = 1;
        for _ in 0..8 {
            ap.infer(&[0.0], 0.005).unwrap(); // 1 ms ≪ 0.5·5 ms ⇒ headroom
        }
        assert_eq!(ap.current_label(), "W1A8");
    }

    #[test]
    fn stays_put_in_the_comfortable_band() {
        // 4 ms against a 5 ms deadline: no miss, but no 2× headroom either.
        let mut ap = ladder(0.004, 0.001);
        for _ in 0..32 {
            ap.infer(&[0.0], 0.005).unwrap();
        }
        assert_eq!(ap.current_label(), "W1A8");
        assert!(ap.switches.is_empty());
    }

    #[test]
    fn never_steps_below_ladder_bottom() {
        let mut ap = ladder(0.010, 0.009);
        for _ in 0..64 {
            ap.infer(&[0.0], 0.001).unwrap(); // everything misses
        }
        assert_eq!(ap.current_index(), 1, "must clamp at the bottom");
    }

    #[test]
    fn oscillation_is_damped_by_windowing() {
        // Alternating hit/miss at exactly the threshold should not flap
        // every frame: switches only happen at window boundaries.
        let mut ap = ladder(0.006, 0.001);
        for i in 0..32 {
            let deadline = if i % 2 == 0 { 0.004 } else { 0.1 };
            ap.infer(&[0.0], deadline).unwrap();
        }
        assert!(
            ap.switches.len() <= 32 / 8,
            "at most one switch per window: {:?}",
            ap.switches
        );
    }
}
