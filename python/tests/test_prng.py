"""Cross-language PRNG parity: these known-answer vectors are asserted
verbatim by ``rust/src/util/rng.rs`` — if either side drifts, the
sim↔runtime numerical cross-check is void."""

import math

from compile.prng import KAT_SEED, KAT_VALUES, SplitMix64, normal_array


def test_known_answer_vector():
    r = SplitMix64(KAT_SEED)
    assert tuple(r.next_u64() for _ in range(3)) == KAT_VALUES


def test_shuffle_parity_with_rust():
    # Pinned in rust/src/util/rng.rs tests as well.
    o = list(range(10))
    SplitMix64(42).shuffle(o)
    assert o == [8, 3, 6, 5, 4, 0, 9, 2, 1, 7]


def test_next_below_parity():
    r = SplitMix64(7)
    assert [r.next_below(100) for _ in range(5)] == [38, 1, 90, 58, 45]


def test_normals_match_rust_boxmuller():
    r = SplitMix64(3)
    vals = [r.next_normal() for _ in range(3)]
    expected = [-0.6410515695, 0.8874808859, -1.1468789924]
    for v, e in zip(vals, expected):
        assert abs(v - e) < 1e-9


def test_f64_in_unit_interval():
    r = SplitMix64(9)
    for _ in range(1000):
        v = r.next_f64()
        assert 0.0 <= v < 1.0


def test_normal_array_is_f32_and_deterministic():
    a = normal_array(SplitMix64(5), 64, 0.02)
    b = normal_array(SplitMix64(5), 64, 0.02)
    assert a.dtype.name == "float32"
    assert (a == b).all()
    assert abs(float(a.mean())) < 0.02


def test_normal_moments():
    r = SplitMix64(11)
    xs = [r.next_normal() for _ in range(20000)]
    mean = sum(xs) / len(xs)
    var = sum((x - mean) ** 2 for x in xs) / len(xs)
    assert abs(mean) < 0.03
    assert abs(var - 1.0) < 0.05
    assert all(math.isfinite(x) for x in xs)
