"""QAT harness smoke + invariants (fast settings)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.data import make_dataset
from compile.train import (
    TrainConfig,
    adamw_init,
    adamw_update,
    cosine_lr,
    make_masks,
    three_stage_train,
)


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = M.micro_vit(embed_dim=16, depth=1, num_heads=2)
    x, y = make_dataset(8, cfg.num_classes, cfg.image_size, seed=0, noise=0.5)
    xt, yt = make_dataset(4, cfg.num_classes, cfg.image_size, seed=1, noise=0.5)
    ds = (
        (np.asarray(M.images_to_patches(jnp.asarray(x), cfg)), y),
        (np.asarray(M.images_to_patches(jnp.asarray(xt), cfg)), yt),
    )
    return cfg, ds


def test_three_stage_smoke(tiny_setup):
    cfg, ds = tiny_setup
    tc = TrainConfig(epochs_pretrain=2, epochs_binary=2, epochs_act=1, batch_size=16)
    params, results = three_stage_train(cfg, tc, dataset=ds, act_bits=8)
    assert [r.name for r in results] == [
        "pretrain-w32a32",
        "binary-w1a32 (progressive)",
        "act-w1a8",
    ]
    for r in results:
        assert 0.0 <= r.test_acc <= 1.0
        assert all(np.isfinite(l) for l in r.loss_curve)


def test_loss_decreases_during_pretrain(tiny_setup):
    cfg, ds = tiny_setup
    tc = TrainConfig(epochs_pretrain=6, epochs_binary=0, epochs_act=0, batch_size=16)
    _, results = three_stage_train(cfg, tc, dataset=ds, act_bits=None)
    curve = results[0].loss_curve
    assert curve[-1] < curve[0], curve


def test_ablation_toggles(tiny_setup):
    cfg, ds = tiny_setup
    tc = TrainConfig(epochs_pretrain=1, epochs_binary=1, epochs_act=0, batch_size=16)
    tc.pretrain = False
    _, r_nopre = three_stage_train(cfg, tc, dataset=ds, act_bits=None)
    assert len(r_nopre) == 1  # no pretrain stage result
    tc2 = TrainConfig(epochs_pretrain=1, epochs_binary=1, epochs_act=0, batch_size=16)
    tc2.progressive = False
    _, r_noprog = three_stage_train(cfg, tc2, dataset=ds, act_bits=None)
    assert "abrupt" in r_noprog[-1].name


def test_masks_cover_all_encoder_weights(tiny_setup):
    cfg, _ = tiny_setup
    params = M.init_params(cfg, seed=3)
    masks = make_masks(params, seed=0)
    assert len(masks) == cfg.depth
    for lm, lp in zip(masks, params["layers"]):
        for key in ("qkv", "proj", "mlp1", "mlp2"):
            assert lm[key].n == int(np.prod(lp[key].shape))


def test_adamw_moves_params():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.ones((4,))}
    state = adamw_init(params)
    new, state = adamw_update(params, grads, state, lr=0.1, wd=0.0)
    assert float(jnp.max(jnp.abs(new["w"] - params["w"]))) > 0
    assert state["t"] == 1


def test_cosine_schedule_endpoints():
    assert abs(cosine_lr(1.0, 0, 10) - 1.0) < 1e-9
    assert cosine_lr(1.0, 10, 10) < 1e-9
    assert 0.4 < cosine_lr(1.0, 5, 10) < 0.6
