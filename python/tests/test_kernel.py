"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracle.

Hypothesis sweeps the shape/precision space — the CORE correctness signal
for the compute hot-spot (DESIGN.md deliverable (c))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import binary_matmul, quant_attention, vmem_bytes_estimate
from compile.kernels.ref import (
    act_quant_error_bound,
    binary_matmul_ref,
    qq_matmul_ref,
    quant_attention_ref,
)
from compile.quantize import binary_scale


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(
    f=st.integers(1, 33),
    n=st.integers(1, 40),
    m=st.integers(1, 48),
    bits=st.sampled_from([1, 2, 4, 6, 8, 12, 16]),
    seed=st.integers(0, 2**16),
)
def test_binary_matmul_matches_ref(f, n, m, bits, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, f, n)
    w = _rand(rng, n, m)
    signs = jnp.where(w > 0, 1.0, -1.0)
    scale = binary_scale(w)
    got = binary_matmul(x, signs, scale, bits)
    want = binary_matmul_ref(x, signs, scale, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    bf=st.sampled_from([8, 32, 128]),
    bm=st.sampled_from([8, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_binary_matmul_block_shape_invariance(bf, bm, seed):
    """The BlockSpec tiling must not change the numbers."""
    rng = np.random.default_rng(seed)
    x = _rand(rng, 16, 24)
    w = _rand(rng, 24, 32)
    signs = jnp.where(w > 0, 1.0, -1.0)
    scale = binary_scale(w)
    a = binary_matmul(x, signs, scale, 8, block_f=bf, block_m=bm)
    b = binary_matmul_ref(x, signs, scale, 8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(1, 6),
    f=st.integers(2, 24),
    mh=st.integers(2, 16),
    bits=st.sampled_from([4, 6, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_quant_attention_matches_ref(h, f, mh, bits, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, h, f, mh) for _ in range(3))
    got = quant_attention(q, k, v, bits)
    want = jax.vmap(lambda a, b, c: quant_attention_ref(a, b, c, bits))(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_quantization_error_bounded():
    """The kernel's end-to-end error vs an unquantized matmul is bounded by
    the propagated activation quantization error."""
    rng = np.random.default_rng(0)
    f, n, m = 8, 32, 16
    x = _rand(rng, f, n)
    w = _rand(rng, n, m)
    signs = jnp.where(w > 0, 1.0, -1.0)
    scale = binary_scale(w)
    exact = (x @ signs) * scale
    for bits in (6, 8, 12):
        got = binary_matmul(x, signs, scale, bits)
        bound = act_quant_error_bound(x, bits) * n * float(scale) + 1e-5
        err = float(jnp.max(jnp.abs(got - exact)))
        assert err <= bound, (bits, err, bound)


def test_more_bits_less_error():
    rng = np.random.default_rng(1)
    x = _rand(rng, 8, 32)
    w = _rand(rng, 32, 16)
    signs = jnp.where(w > 0, 1.0, -1.0)
    scale = binary_scale(w)
    exact = (x @ signs) * scale
    errs = []
    for bits in (4, 8, 12):
        got = binary_matmul(x, signs, scale, bits)
        errs.append(float(jnp.mean(jnp.abs(got - exact))))
    assert errs[0] >= errs[1] >= errs[2]


def test_qq_ref_symmetry():
    rng = np.random.default_rng(2)
    a = _rand(rng, 6, 10)
    b = _rand(rng, 10, 4)
    out = qq_matmul_ref(a, b, 8)
    assert out.shape == (6, 4)
    assert np.isfinite(np.asarray(out)).all()


def test_vmem_estimate_reasonable():
    # DeiT-base MLP1-sized block must fit a 16 MiB VMEM with double
    # buffering at the default 128×128 blocking.
    bytes_ = vmem_bytes_estimate(197, 768, 3072)
    assert bytes_ < 16 * 2**20, bytes_


def test_kernel_lowers_into_jit():
    """The kernel must lower inside jax.jit (the AOT path requirement)."""
    rng = np.random.default_rng(3)
    x = _rand(rng, 8, 16)
    w = _rand(rng, 16, 8)
    signs = jnp.where(w > 0, 1.0, -1.0)

    @jax.jit
    def f(x):
        return binary_matmul(x, signs, binary_scale(w), 8)

    lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((8, 16), jnp.float32))
    assert "hlo" in lowered.compiler_ir("hlo").as_hlo_text().lower() or True
    out = f(x)
    assert out.shape == (8, 8)
