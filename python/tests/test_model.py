"""L2 model semantics: shapes, Fig. 4 conv→FC equivalence, pallas-path
parity, quantization behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def micro():
    cfg = M.micro_vit(embed_dim=32, depth=2, num_heads=4)
    params = M.init_params(cfg, seed=11)
    rng = np.random.default_rng(0)
    patches = jnp.asarray(rng.normal(size=(cfg.num_patches, cfg.patch_in)).astype(np.float32))
    return cfg, params, patches


def test_forward_shapes(micro):
    cfg, params, patches = micro
    logits = M.forward(params, patches, cfg)
    assert logits.shape == (cfg.num_classes,)
    batch = jnp.stack([patches, patches * 0.5])
    lb = M.forward_batch(params, batch, cfg, act_bits=8, w_bits=1)
    assert lb.shape == (2, cfg.num_classes)


def test_patch_conv_fc_equivalence(micro):
    """Fig. 4: the patch-embed conv (kernel=stride=P) equals the FC over
    flattened patches."""
    cfg, params, _ = micro
    rng = np.random.default_rng(1)
    img = jnp.asarray(rng.normal(size=(1, cfg.image_size, cfg.image_size, 3)).astype(np.float32))
    patches = M.images_to_patches(img, cfg)[0]
    fc_out = patches @ params["patch"]

    # Direct strided conv with the same kernel layout (C, P, P) → M.
    w = np.asarray(params["patch"]).reshape(3, cfg.patch_size, cfg.patch_size, cfg.embed_dim)
    p = cfg.patch_size
    conv_rows = []
    for i in range(cfg.image_size // p):
        for j in range(cfg.image_size // p):
            window = np.asarray(img[0, i * p : (i + 1) * p, j * p : (j + 1) * p, :])
            # window (P,P,C) → (C,P,P) to match images_to_patches layout.
            flatw = np.transpose(window, (2, 0, 1)).reshape(-1)
            conv_rows.append(flatw @ np.asarray(params["patch"]))
    conv_out = np.stack(conv_rows)
    np.testing.assert_allclose(np.asarray(fc_out), conv_out, rtol=1e-4, atol=1e-5)
    _ = w


def test_pallas_path_matches_jnp_path(micro):
    cfg, params, patches = micro
    for bits in (8, 6):
        a = M.forward(params, patches, cfg, act_bits=bits, w_bits=1, use_pallas=False)
        b = M.forward(params, patches, cfg, act_bits=bits, w_bits=1, use_pallas=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-3)


def test_quantized_converges_to_fp_in_bits(micro):
    cfg, params, patches = micro
    l16 = M.forward(params, patches, cfg, act_bits=16, w_bits=1)
    d = lambda x: float(jnp.linalg.norm(x - l16))
    d12 = d(M.forward(params, patches, cfg, act_bits=12, w_bits=1))
    d4 = d(M.forward(params, patches, cfg, act_bits=4, w_bits=1))
    assert d12 < d4


def test_binary_weights_change_function(micro):
    cfg, params, patches = micro
    fp = M.forward(params, patches, cfg)
    bn = M.forward(params, patches, cfg, act_bits=None, w_bits=1)
    assert float(jnp.linalg.norm(fp - bn)) > 0


def test_ste_eval_matches_inference_path(micro):
    """The QAT forward (ste=True) must produce the same values as the
    inference fake-quant path (STE only changes gradients)."""
    cfg, params, patches = micro
    a = M.forward(params, patches, cfg, act_bits=6, w_bits=1, ste=False)
    b = M.forward(params, patches, cfg, act_bits=6, w_bits=1, ste=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_init_params_matches_rust_draw_order():
    """First draws are the patch matrix — pinned against
    sim::weights::known_answer_first_weight."""
    from compile.prng import SplitMix64

    cfg = M.deit_tiny()
    params = M.init_params(cfg, seed=42)
    r = SplitMix64(42)
    expected = np.float32(np.float32(r.next_normal()) * np.float32(0.02))
    assert params["patch"].flat[0] == expected


def test_images_to_patches_shape():
    cfg = M.micro_vit()
    imgs = jnp.zeros((3, cfg.image_size, cfg.image_size, 3))
    p = M.images_to_patches(imgs, cfg)
    assert p.shape == (3, cfg.num_patches, cfg.patch_in)
