"""Quantization math (paper §4.2 / Eq. 5 / Eq. 6) — mirrors the Rust
quant test suite."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.quantize import (
    ProgressiveMask,
    binarize,
    binary_scale,
    fake_quant_act,
    progressive_schedule,
    qmax_for,
    ste_binarize,
    ste_quant_act,
)


def test_binarize_scale_is_l1_over_n():
    w = jnp.asarray([[1.0, -2.0], [3.0, -4.0]])
    assert abs(float(binary_scale(w)) - 2.5) < 1e-6
    b = np.asarray(binarize(w))
    np.testing.assert_allclose(np.sign(b), [[1, -1], [1, -1]])


def test_binarize_zero_maps_negative():
    b = np.asarray(binarize(jnp.asarray([0.0, 0.5])))
    assert b[0] < 0 and b[1] > 0


@settings(max_examples=30, deadline=None)
@given(bits=st.sampled_from([2, 4, 6, 8, 12, 16]), seed=st.integers(0, 1000))
def test_fake_quant_roundtrip_error_bounded(bits, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=128).astype(np.float32) * 3)
    y = fake_quant_act(x, bits)
    step = float(jnp.max(jnp.abs(x))) / qmax_for(bits)
    assert float(jnp.max(jnp.abs(x - y))) <= step / 2 + 1e-6


def test_fake_quant_monotone_in_bits():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=256).astype(np.float32))
    mse = lambda b: float(jnp.mean((fake_quant_act(x, b) - x) ** 2))
    assert mse(8) <= mse(6) <= mse(4) <= mse(2)


def test_ste_forward_equals_quantized():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=64).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(ste_quant_act(x, 6)), np.asarray(fake_quant_act(x, 6)), rtol=1e-7
    )
    w = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(ste_binarize(w)), np.asarray(binarize(w)), rtol=1e-7
    )


def test_ste_gradient_passes_through():
    import jax

    g = jax.grad(lambda x: jnp.sum(ste_quant_act(x, 8) ** 2))(jnp.asarray([0.5, -1.0]))
    # d/dx x² through STE = 2·q(x) ≈ 2x.
    assert np.isfinite(np.asarray(g)).all()
    assert abs(float(g[0]) - 1.0) < 0.1


def test_progressive_mask_monotone_and_deterministic():
    a = ProgressiveMask(100, 42)
    b = ProgressiveMask(100, 42)
    a.set_fraction(0.5)
    b.set_fraction(0.5)
    assert (a.dense() == b.dense()).all()
    before = a.dense().copy()
    a.set_fraction(0.25)  # monotone: no un-binarization
    assert (a.dense() == before).all()
    a.set_fraction(0.9)
    after = a.dense()
    assert (~before | after).all()
    assert after.sum() == 90


def test_progressive_blend_counts():
    m = ProgressiveMask(16, 3)
    m.set_fraction(0.5)
    real = jnp.ones(16)
    binary = -jnp.ones(16)
    out = np.asarray(m.blend(real, binary))
    assert (out == -1).sum() == 8


def test_schedule_linear():
    assert progressive_schedule(0, 300) == 0.0
    assert progressive_schedule(299, 300) == 1.0
    assert abs(progressive_schedule(150, 300) - 0.5017) < 1e-3
