"""Synthetic data + cross-language input-stream parity."""

import numpy as np

from compile import model as M
from compile.data import make_dataset, synthetic_patches


def test_dataset_shapes_and_labels():
    x, y = make_dataset(5, 10, 32, seed=0)
    assert x.shape == (50, 32, 32, 3)
    assert sorted(set(y.tolist())) == list(range(10))
    assert np.isfinite(x).all()


def test_dataset_deterministic_and_noise_sensitivity():
    a, ya = make_dataset(3, 4, 32, seed=7)
    b, yb = make_dataset(3, 4, 32, seed=7)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(ya, yb)
    c, _ = make_dataset(3, 4, 32, seed=8)
    assert not np.array_equal(a, c)
    lo, _ = make_dataset(3, 4, 32, seed=7, noise=0.0)
    hi, _ = make_dataset(3, 4, 32, seed=7, noise=2.0)
    assert hi.std() > lo.std()


def test_classes_are_distinguishable_without_noise():
    # Mean inter-class distance must exceed intra-class distance.
    x, y = make_dataset(6, 4, 32, seed=1, noise=0.0)
    feats = x.reshape(len(y), -1)
    intra, inter = [], []
    for i in range(len(y)):
        for j in range(i + 1, len(y)):
            d = float(np.linalg.norm(feats[i] - feats[j]))
            (intra if y[i] == y[j] else inter).append(d)
    assert np.mean(inter) > np.mean(intra)


def test_synthetic_patches_matches_rust_stream():
    """Mirrors sim::weights::VitWeights::synthetic_patches — the PRNG
    stream (seed ^ 0x5EED_F00D ^ frame_id·0x9E37) and the f32 range
    arithmetic must match the Rust implementation exactly. The end-to-end
    guarantee is exercised by the rust sim_vs_runtime integration test;
    here we check stream determinism and frame separation."""
    cfg = M.micro_vit()
    a = synthetic_patches(cfg, 11, 0)
    b = synthetic_patches(cfg, 11, 0)
    c = synthetic_patches(cfg, 11, 1)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.shape == (cfg.num_patches, cfg.patch_in)
    assert (a >= -1.0).all() and (a < 1.0).all()


def test_patches_layout_matches_images_to_patches():
    import jax.numpy as jnp

    cfg = M.micro_vit()
    x, _ = make_dataset(1, 2, cfg.image_size, seed=2)
    p = M.images_to_patches(jnp.asarray(x), cfg)
    assert p.shape == (2, cfg.num_patches, cfg.patch_in)
    # First patch, first channel-block equals the image's top-left window
    # in (C, P, P) order.
    win = np.transpose(np.asarray(x[0, :8, :8, :]), (2, 0, 1)).reshape(-1)
    np.testing.assert_allclose(np.asarray(p[0, 0]), win, rtol=1e-6)
