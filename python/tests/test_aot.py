"""AOT export path: HLO text artifacts + params dump + manifest."""

import json
import os
import struct

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    cfg = M.micro_vit(embed_dim=16, depth=1, num_heads=2)
    entry = aot.export_variant(cfg, act_bits=8, w_bits=1, seed=11, out_dir=out)
    return out, cfg, entry


def test_hlo_text_is_parseable_hlo(exported):
    out, _, entry = exported
    text = open(os.path.join(out, entry["hlo"])).read()
    assert text.startswith("HloModule"), text[:80]
    # The tuple-return convention the Rust loader expects.
    assert "ROOT" in text
    assert len(text) > 1000


def test_params_bin_roundtrip(exported):
    out, cfg, entry = exported
    raw = open(os.path.join(out, entry["params"]), "rb").read()
    n = entry["param_count"]
    vals = struct.unpack(f"<{n}f", raw)
    params = M.init_params(cfg, seed=11)
    flat, _ = aot.flatten_params(params)
    np.testing.assert_allclose(np.asarray(vals), flat, rtol=0, atol=0)


def test_flatten_unflatten_roundtrip():
    cfg = M.micro_vit(embed_dim=16, depth=2, num_heads=2)
    params = M.init_params(cfg, seed=5)
    flat, spec = aot.flatten_params(params)
    import jax.numpy as jnp

    back = aot.unflatten_params(jnp.asarray(flat), spec, cfg)
    np.testing.assert_array_equal(np.asarray(back["patch"]), params["patch"])
    np.testing.assert_array_equal(
        np.asarray(back["layers"][1]["mlp2"]), params["layers"][1]["mlp2"]
    )
    np.testing.assert_array_equal(np.asarray(back["head"]), params["head"])


def test_manifest_entry_fields(exported):
    _, cfg, entry = exported
    for key in ("tag", "act_bits", "w_bits", "hlo", "params", "patches_shape", "config"):
        assert key in entry
    assert entry["patches_shape"] == [cfg.num_patches, cfg.patch_in]
    assert entry["config"]["embed_dim"] == cfg.embed_dim


def test_exported_hlo_differs_by_precision(tmp_path):
    cfg = M.micro_vit(embed_dim=16, depth=1, num_heads=2)
    e8 = aot.export_variant(cfg, 8, 1, 11, str(tmp_path))
    e32 = aot.export_variant(cfg, None, 32, 11, str(tmp_path))
    t8 = open(os.path.join(str(tmp_path), e8["hlo"])).read()
    t32 = open(os.path.join(str(tmp_path), e32["hlo"])).read()
    assert t8 != t32
    # Quantized graph must contain rounding ops; fp graph must not.
    assert "round" in t8.lower()
    assert "round" not in t32.lower()


def test_repo_manifest_exists():
    """`make artifacts` output (built in CI/this repo) is well-formed."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    man = json.load(open(path))
    assert man["variants"], man
    tags = {v["tag"] for v in man["variants"]}
    assert {"micro_w32a32", "micro_w1a8", "micro_w1a6"} <= tags
