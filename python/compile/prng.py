"""SplitMix64 PRNG — bit-exact mirror of ``rust/src/util/rng.rs``.

The Rust simulator and this compile-time Python stack must generate
*identical* ViT parameters from a seed, so the functional simulator and the
AOT-compiled JAX model can be cross-checked numerically. Keep every detail
(mask widths, Box–Muller branch, draw order) in lockstep with the Rust
implementation; ``tests/test_prng.py`` pins known-answer vectors shared by
both sides.
"""

from __future__ import annotations

import math

import numpy as np

_M64 = (1 << 64) - 1


class SplitMix64:
    """Deterministic 64-bit PRNG (same constants as the Rust side)."""

    def __init__(self, seed: int) -> None:
        self.state = seed & _M64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & _M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
        return (z ^ (z >> 31)) & _M64

    def next_below(self, n: int) -> int:
        """Uniform in [0, n) — Lemire-style mapping, as in Rust."""
        if n == 0:
            return 0
        return ((self.next_u64() >> 11) * n) >> 53

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) / float(1 << 53)

    def next_f32_range(self, lo: float, hi: float) -> float:
        # Rust: lo + (hi - lo) * (next_f64() as f32); f64 multiply happens
        # in f32? No — `self.next_f64() as f32` then f32 arithmetic.
        r = np.float32(self.next_f64())
        return float(np.float32(lo) + (np.float32(hi) - np.float32(lo)) * r)

    def next_normal(self) -> float:
        """Box–Muller, cosine branch (matches Rust exactly in f64)."""
        u1 = max(self.next_f64(), 1e-12)
        u2 = self.next_f64()
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)

    def shuffle(self, data: list) -> None:
        """Fisher–Yates, same order as Rust's ``SplitMix64::shuffle``."""
        for i in range(len(data) - 1, 0, -1):
            j = self.next_below(i + 1)
            data[i], data[j] = data[j], data[i]


def normal_array(rng: SplitMix64, n: int, std: float) -> np.ndarray:
    """N(0, std²) draws as f32 — mirrors ``sim::weights::normal_vec``:
    the Rust side computes ``next_normal() as f32 * std`` in f32."""
    out = np.empty(n, dtype=np.float32)
    for i in range(n):
        out[i] = np.float32(np.float32(rng.next_normal()) * np.float32(std))
    return out


# Known-answer vector shared with rust/src/util/rng.rs::known_answer_vector.
KAT_SEED = 42
KAT_VALUES = (
    13679457532755275413,
    2949826092126892291,
    5139283748462763858,
)
