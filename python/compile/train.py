"""QAT training harness (paper §4.2 + §6.2) at reproduction scale.

Implements the paper's three-step recipe on the synthetic dataset
(DESIGN.md §Substitutions — the repo cannot train 86M-parameter DeiT-base
on ImageNet for 3×300 epochs):

1. **Pre-train** a full-precision ViT from scratch;
2. **Progressive binary finetune** — binary weights phased in linearly
   via the Eq. 6 mask (0% → 100% over the stage);
3. **Activation-quantization finetune** at the target precision.

The optimizer is AdamW with cosine decay (§6.1), implemented in-tree.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .data import make_dataset
from .quantize import ProgressiveMask, progressive_schedule


@dataclass
class TrainConfig:
    epochs_pretrain: int = 24
    epochs_binary: int = 24
    epochs_act: int = 12
    batch_size: int = 64
    lr: float = 1e-3
    weight_decay: float = 0.05
    seed: int = 0
    progressive: bool = True
    pretrain: bool = True


@dataclass
class StageResult:
    name: str
    train_acc: float
    test_acc: float
    loss_curve: list = field(default_factory=list)


# ---------------------------------------------------------------------------
# In-tree AdamW (no optax offline).
# ---------------------------------------------------------------------------


def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adamw_update(params, grads, state, lr, wd, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, mi, vi: p
        - lr * (mi * mhat_scale / (jnp.sqrt(vi * vhat_scale) + eps) + wd * p),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def cosine_lr(base: float, epoch: int, total: int) -> float:
    return float(base * 0.5 * (1 + np.cos(np.pi * epoch / max(total, 1))))


# ---------------------------------------------------------------------------
# Training loop.
# ---------------------------------------------------------------------------


def _loss_fn(params, patches, labels, cfg, act_bits, w_bits, masks):
    logits = M.forward_batch(
        params,
        patches,
        cfg,
        act_bits=act_bits,
        w_bits=w_bits,
        ste=True,
        masks=masks,
    )
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    return nll


def _accuracy(params, patches, labels, cfg, act_bits, w_bits, masks=None):
    # Evaluation uses the inference path (hard quantization, no STE).
    logits = M.forward_batch(
        params, patches, cfg, act_bits=act_bits, w_bits=w_bits, ste=True, masks=masks
    )
    return float((jnp.argmax(logits, axis=1) == labels).mean())


def _run_stage(
    name: str,
    params,
    data,
    cfg: M.VitConfig,
    tc: TrainConfig,
    epochs: int,
    act_bits,
    w_bits,
    progressive_masks=None,
):
    """One training stage; `progressive_masks` enables the Eq. 6 schedule."""
    (xtr, ytr), (xte, yte) = data
    state = adamw_init(params)
    n = xtr.shape[0]
    steps = max(n // tc.batch_size, 1)
    rng = np.random.default_rng(tc.seed + hash(name) % 1000)
    loss_curve = []

    grad_fn = jax.jit(
        jax.value_and_grad(_loss_fn),
        static_argnames=("cfg", "act_bits", "w_bits"),
    )

    for epoch in range(epochs):
        if progressive_masks is not None:
            p = progressive_schedule(epoch, epochs)
            for layer_masks in progressive_masks:
                for mask in layer_masks.values():
                    mask.set_fraction(p)
            masks = [
                {k: v.dense() for k, v in lm.items()} for lm in progressive_masks
            ]
        else:
            masks = None
        lr = cosine_lr(tc.lr, epoch, epochs)
        perm = rng.permutation(n)
        epoch_loss = 0.0
        for s in range(steps):
            idx = perm[s * tc.batch_size : (s + 1) * tc.batch_size]
            loss, grads = grad_fn(
                params,
                jnp.asarray(xtr[idx]),
                jnp.asarray(ytr[idx]),
                cfg,
                act_bits,
                w_bits,
                masks,
            )
            params, state = adamw_update(params, grads, state, lr, tc.weight_decay)
            epoch_loss += float(loss)
        loss_curve.append(epoch_loss / steps)

    final_masks = None
    if progressive_masks is not None:
        final_masks = [
            {k: v.dense() for k, v in lm.items()} for lm in progressive_masks
        ]
    result = StageResult(
        name=name,
        train_acc=_accuracy(params, jnp.asarray(xtr), jnp.asarray(ytr), cfg, act_bits, w_bits, final_masks),
        test_acc=_accuracy(params, jnp.asarray(xte), jnp.asarray(yte), cfg, act_bits, w_bits, final_masks),
        loss_curve=loss_curve,
    )
    return params, result


def make_masks(params, seed: int):
    """Per-layer, per-matrix progressive masks (Eq. 6)."""
    masks = []
    for i, lp in enumerate(params["layers"]):
        masks.append(
            {
                k: ProgressiveMask(int(np.prod(lp[k].shape)), seed * 1000 + i * 10 + j)
                for j, k in enumerate(("qkv", "proj", "mlp1", "mlp2"))
            }
        )
    return masks


def three_stage_train(
    cfg: M.VitConfig,
    tc: TrainConfig,
    dataset=None,
    act_bits: int | None = 8,
):
    """The full paper recipe. Returns (params, [StageResult...]).

    Toggles (`tc.pretrain`, `tc.progressive`) implement the Table 4
    ablations; `act_bits=None` stops after stage 2 (the W1A32 row).
    """
    if dataset is None:
        x, y = make_dataset(60, cfg.num_classes, cfg.image_size, seed=tc.seed)
        xt, yt = make_dataset(20, cfg.num_classes, cfg.image_size, seed=tc.seed + 1)
        patches = np.asarray(M.images_to_patches(jnp.asarray(x), cfg))
        patches_t = np.asarray(M.images_to_patches(jnp.asarray(xt), cfg))
        dataset = ((patches, y), (patches_t, yt))

    params = M.init_params(cfg, seed=tc.seed + 100)
    params = jax.tree_util.tree_map(jnp.asarray, params)
    results = []

    # Stage 1: full-precision pre-training.
    if tc.pretrain:
        params, r1 = _run_stage(
            "pretrain-w32a32", params, dataset, cfg, tc, tc.epochs_pretrain, None, 32
        )
        results.append(r1)

    # Stage 2: binary-weight finetuning (progressive or abrupt).
    masks = make_masks(params, tc.seed) if tc.progressive else None
    if masks is None:
        # w/o progressive: all weights binarized from epoch 0 (the harder
        # loss landscape the paper's ablation shows is worse).
        abrupt = make_masks(params, tc.seed)
        for lm in abrupt:
            for m in lm.values():
                m.set_fraction(1.0)
        masks = abrupt
        # Freeze at 100% by skipping the schedule.
        params, r2 = _run_stage(
            "binary-w1a32 (abrupt)",
            params,
            dataset,
            cfg,
            tc,
            tc.epochs_binary,
            None,
            1,
            progressive_masks=None if False else masks,
        )
    else:
        params, r2 = _run_stage(
            "binary-w1a32 (progressive)",
            params,
            dataset,
            cfg,
            tc,
            tc.epochs_binary,
            None,
            1,
            progressive_masks=masks,
        )
    results.append(r2)

    # Stage 3: activation quantization finetuning.
    if act_bits is not None:
        full = make_masks(params, tc.seed)
        for lm in full:
            for m in lm.values():
                m.set_fraction(1.0)
        params, r3 = _run_stage(
            f"act-w1a{act_bits}",
            params,
            dataset,
            cfg,
            tc,
            tc.epochs_act,
            act_bits,
            1,
            progressive_masks=full,
        )
        results.append(r3)

    return params, results
