"""AOT export: lower the L2 model to HLO **text** for the Rust runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (one per model variant / precision):

    artifacts/<name>_w<wb>a<ab>.hlo.txt      the lowered forward pass
    artifacts/<name>_w<wb>a<ab>.params.bin   f32 LE dump of the flat params
    artifacts/manifest.json                  shapes + metadata for Rust

The lowered computation signature is ``(flat_params, patches) ->
(logits,)`` so the Rust side feeds parameters as one literal. Parameters
are drawn from the SplitMix64 stream shared with the Rust simulator
(same seed ⇒ same model on both sides).
"""

from __future__ import annotations

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def flatten_params(params: dict) -> tuple[np.ndarray, list]:
    """Flatten to one f32 vector + a spec [(name, shape, offset), ...]."""
    chunks = []
    spec = []
    off = 0

    def push(name: str, a: np.ndarray):
        nonlocal off
        a = np.asarray(a, dtype=np.float32)
        chunks.append(a.ravel())
        spec.append({"name": name, "shape": list(a.shape), "offset": off})
        off += a.size

    push("patch", params["patch"])
    push("cls", params["cls"])
    push("pos", params["pos"])
    for i, lp in enumerate(params["layers"]):
        for key in ("qkv", "proj", "mlp1", "mlp2"):
            push(f"l{i}.{key}", lp[key])
    push("head", params["head"])
    return np.concatenate(chunks), spec


def unflatten_params(flat: jnp.ndarray, spec: list, cfg: M.VitConfig) -> dict:
    by_name = {}
    for s in spec:
        size = int(np.prod(s["shape"]))
        by_name[s["name"]] = flat[s["offset"] : s["offset"] + size].reshape(s["shape"])
    params = {
        "patch": by_name["patch"],
        "cls": by_name["cls"],
        "pos": by_name["pos"],
        "layers": [
            {key: by_name[f"l{i}.{key}"] for key in ("qkv", "proj", "mlp1", "mlp2")}
            for i in range(cfg.depth)
        ],
        "head": by_name["head"],
    }
    return params


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_variant(
    cfg: M.VitConfig,
    act_bits: int | None,
    w_bits: int,
    seed: int,
    out_dir: str,
    use_pallas: bool = True,
) -> dict:
    """Lower one (model, precision) variant; returns its manifest entry."""
    params = M.init_params(cfg, seed)
    flat, spec = flatten_params(params)

    def fn(flat_params, patches):
        p = unflatten_params(flat_params, spec, cfg)
        return (
            M.forward(
                p,
                patches,
                cfg,
                act_bits=act_bits,
                w_bits=w_bits,
                use_pallas=use_pallas,
            ),
        )

    flat_spec = jax.ShapeDtypeStruct(flat.shape, jnp.float32)
    patch_spec = jax.ShapeDtypeStruct((cfg.num_patches, cfg.patch_in), jnp.float32)
    lowered = jax.jit(fn).lower(flat_spec, patch_spec)
    hlo = to_hlo_text(lowered)

    tag = f"{cfg.name}_w{w_bits}a{act_bits if act_bits else 32}"
    hlo_path = os.path.join(out_dir, f"{tag}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)
    params_path = os.path.join(out_dir, f"{tag}.params.bin")
    with open(params_path, "wb") as f:
        f.write(struct.pack(f"<{flat.size}f", *flat.tolist()))

    return {
        "tag": tag,
        "model": cfg.name,
        "act_bits": act_bits if act_bits else 32,
        "w_bits": w_bits,
        "seed": seed,
        "hlo": os.path.basename(hlo_path),
        "params": os.path.basename(params_path),
        "param_count": int(flat.size),
        "patches_shape": [cfg.num_patches, cfg.patch_in],
        "num_classes": cfg.num_classes,
        "config": {
            "image_size": cfg.image_size,
            "patch_size": cfg.patch_size,
            "in_chans": cfg.in_chans,
            "embed_dim": cfg.embed_dim,
            "depth": cfg.depth,
            "num_heads": cfg.num_heads,
            "mlp_ratio": cfg.mlp_ratio,
            "num_classes": cfg.num_classes,
        },
    }


DEFAULT_SEED = 11


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=DEFAULT_SEED)
    ap.add_argument(
        "--full",
        action="store_true",
        help="also export DeiT-tiny (slow lowering; micro variants are the default)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"seed": args.seed, "variants": []}
    micro = M.micro_vit()
    # The serving/cross-check variants: fp32 baseline + the paper's two
    # headline precisions + the 1-bit FR_max probe.
    for act_bits, w_bits in ((None, 32), (8, 1), (6, 1), (4, 1)):
        entry = export_variant(micro, act_bits, w_bits, args.seed, args.out_dir)
        print(f"exported {entry['tag']} ({entry['param_count']} params)")
        manifest["variants"].append(entry)

    if args.full:
        tiny = M.deit_tiny()
        for act_bits, w_bits in ((8, 1),):
            entry = export_variant(tiny, act_bits, w_bits, args.seed, args.out_dir)
            print(f"exported {entry['tag']}")
            manifest["variants"].append(entry)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['variants'])} variants")


if __name__ == "__main__":
    main()
