"""L2: the quantized ViT forward pass in JAX (paper §4).

Semantics mirror ``rust/src/sim/exec.rs`` line for line (same LayerNorm
eps, same GELU approximation, same per-tensor / per-head quantization
boundaries), and parameters are drawn from the same SplitMix64 stream
(``init_params`` ↔ ``sim::weights::generate_weights``), so logits from
the AOT-compiled model and the Rust cycle-level simulator agree to
fixed-point tolerance.

Weight modes:
* ``w_bits=32`` — real-valued weights (the W32A32 baseline);
* ``w_bits=1``  — binary weights per Eq. 5 (all encoder matmuls).

Activation ``act_bits``: None (full precision) or 1..=16.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .prng import SplitMix64, normal_array
from .quantize import binarize, binary_scale, fake_quant_act, ste_binarize, ste_quant_act
from .kernels import binary_matmul, quant_attention


@dataclass(frozen=True)
class VitConfig:
    """Mirror of ``rust/src/model/vit.rs::VitConfig``."""

    name: str
    image_size: int
    patch_size: int
    in_chans: int
    embed_dim: int
    depth: int
    num_heads: int
    mlp_ratio: int
    num_classes: int

    @property
    def num_patches(self) -> int:
        return (self.image_size // self.patch_size) ** 2

    @property
    def tokens(self) -> int:
        return self.num_patches + 1

    @property
    def head_dim(self) -> int:
        return self.embed_dim // self.num_heads

    @property
    def patch_in(self) -> int:
        return self.in_chans * self.patch_size * self.patch_size


def deit_tiny() -> VitConfig:
    return VitConfig("deit-tiny", 224, 16, 3, 192, 12, 3, 4, 1000)


def deit_small() -> VitConfig:
    return VitConfig("deit-small", 224, 16, 3, 384, 12, 6, 4, 1000)


def deit_base() -> VitConfig:
    return VitConfig("deit-base", 224, 16, 3, 768, 12, 12, 4, 1000)


def micro_vit(
    image_size: int = 32,
    patch_size: int = 8,
    embed_dim: int = 32,
    depth: int = 2,
    num_heads: int = 4,
    num_classes: int = 10,
) -> VitConfig:
    """The scaled-down ViT used for functional cross-checks and the QAT
    experiments (DESIGN.md §Substitutions)."""
    return VitConfig(
        "micro", image_size, patch_size, 3, embed_dim, depth, num_heads, 4, num_classes
    )


def init_params(cfg: VitConfig, seed: int) -> dict:
    """Draw parameters in the exact order of
    ``sim::weights::generate_weights`` (patch, cls, pos, per layer
    qkv/proj/mlp1/mlp2, head; std 0.02; biases zero / LN non-affine)."""
    rng = SplitMix64(seed)
    m = cfg.embed_dim
    f = cfg.tokens
    hidden = m * cfg.mlp_ratio
    std = 0.02

    def draw(rows: int, cols: int) -> np.ndarray:
        return normal_array(rng, rows * cols, std).reshape(rows, cols)

    params = {
        "patch": draw(cfg.patch_in, m),
        "cls": normal_array(rng, m, std),
        "pos": draw(f, m),
        "layers": [],
        "head": None,
    }
    for _ in range(cfg.depth):
        params["layers"].append(
            {
                "qkv": draw(m, 3 * m),
                "proj": draw(m, m),
                "mlp1": draw(m, hidden),
                "mlp2": draw(hidden, m),
            }
        )
    params["head"] = draw(m, cfg.num_classes)
    return params


def layer_norm(x: jnp.ndarray) -> jnp.ndarray:
    """Non-affine LN over the last axis, eps = 1e-6 (matches Rust)."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + 1e-6)


def _softmax(x: jnp.ndarray) -> jnp.ndarray:
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    act_bits: int | None,
    w_bits: int,
    use_pallas: bool,
    ste: bool,
) -> jnp.ndarray:
    """One encoder linear under the quantization regime."""
    if w_bits == 32 and act_bits is None:
        return x @ w
    if w_bits == 1:
        if ste:
            # QAT path: STE binarization + STE activation quantization.
            xq = ste_quant_act(x, act_bits) if act_bits else x
            return xq @ ste_binarize(w)
        if use_pallas:
            signs = jnp.where(w > 0, 1.0, -1.0).astype(x.dtype)
            return binary_matmul(x, signs, binary_scale(w), act_bits or 16)
        xq = fake_quant_act(x, act_bits) if act_bits else x
        return xq @ binarize(w)
    # w full precision but activations quantized (not used by the paper's
    # main configs; kept for ablations).
    xq = fake_quant_act(x, act_bits) if act_bits else x
    return xq @ w


def forward(
    params: dict,
    patches: jnp.ndarray,
    cfg: VitConfig,
    act_bits: int | None = None,
    w_bits: int = 32,
    use_pallas: bool = False,
    ste: bool = False,
    masks: list | None = None,
) -> jnp.ndarray:
    """Single-image forward: ``patches`` is (N_p, 3·P²) — the Fig. 4
    flattened-patch view. Returns (num_classes,) logits.

    ``masks``: optional per-layer dict of Eq. 6 progressive-binarization
    masks ({name: bool array}) used during QAT stage 2.
    """
    m = cfg.embed_dim
    nh = cfg.num_heads
    mh = cfg.head_dim
    quant = act_bits is not None

    def enc_weight(lp: dict, name: str, li: int) -> jnp.ndarray:
        w = lp[name]
        if masks is not None:
            # Eq. 6: blend binary and real under the progressive mask.
            wb = ste_binarize(w) if ste else binarize(w)
            mask = jnp.asarray(masks[li][name].reshape(w.shape))
            return jnp.where(mask, wb, w)
        return w

    # Patch embedding (never quantized) + CLS + positional embedding.
    x = patches @ params["patch"]
    x = jnp.concatenate([params["cls"][None, :], x], axis=0) + params["pos"]

    for li, lp in enumerate(params["layers"]):
        h = layer_norm(x)
        if masks is not None:
            # Progressive QAT: blended weights, STE activations.
            wq = enc_weight(lp, "qkv", li)
            hq = ste_quant_act(h, act_bits) if quant and ste else (
                fake_quant_act(h, act_bits) if quant else h
            )
            qkv = hq @ wq
        else:
            qkv = _linear(h, lp["qkv"], act_bits, w_bits, use_pallas, ste)

        qkv_h = qkv.reshape(cfg.tokens, 3, nh, mh)
        q = jnp.transpose(qkv_h[:, 0], (1, 0, 2))  # (H, F, Mh)
        k = jnp.transpose(qkv_h[:, 1], (1, 0, 2))
        v = jnp.transpose(qkv_h[:, 2], (1, 0, 2))

        if quant:
            if use_pallas:
                attn = quant_attention(q, k, v, act_bits)
            else:
                fq = lambda t: (ste_quant_act(t, act_bits) if ste else fake_quant_act(t, act_bits))
                # Per-head dynamic scales (vmap over the head axis).
                def one_head(qh, kh, vh):
                    s = fq(qh) @ fq(kh).T / jnp.sqrt(jnp.asarray(mh, dtype=qh.dtype))
                    return fq(_softmax(s)) @ fq(vh)

                attn = jax.vmap(one_head)(q, k, v)
        else:
            def one_head_fp(qh, kh, vh):
                s = qh @ kh.T / jnp.sqrt(jnp.asarray(mh, dtype=qh.dtype))
                return _softmax(s) @ vh

            attn = jax.vmap(one_head_fp)(q, k, v)

        attn = jnp.transpose(attn, (1, 0, 2)).reshape(cfg.tokens, m)

        if masks is not None:
            wp = enc_weight(lp, "proj", li)
            aq = ste_quant_act(attn, act_bits) if quant and ste else (
                fake_quant_act(attn, act_bits) if quant else attn
            )
            x = x + aq @ wp
        else:
            x = x + _linear(attn, lp["proj"], act_bits, w_bits, use_pallas, ste)

        h2 = layer_norm(x)
        if masks is not None:
            w1 = enc_weight(lp, "mlp1", li)
            w2 = enc_weight(lp, "mlp2", li)
            h2q = ste_quant_act(h2, act_bits) if quant and ste else (
                fake_quant_act(h2, act_bits) if quant else h2
            )
            g = jax.nn.gelu(h2q @ w1, approximate=True)
            gq = ste_quant_act(g, act_bits) if quant and ste else (
                fake_quant_act(g, act_bits) if quant else g
            )
            x = x + gq @ w2
        else:
            g = jax.nn.gelu(
                _linear(h2, lp["mlp1"], act_bits, w_bits, use_pallas, ste),
                approximate=True,
            )
            x = x + _linear(g, lp["mlp2"], act_bits, w_bits, use_pallas, ste)

    # Output head on the CLS token (never quantized).
    return layer_norm(x[0]) @ params["head"]


def forward_batch(params, patches, cfg, **kw):
    """vmap of :func:`forward` over a leading batch axis."""
    return jax.vmap(lambda p: forward(params, p, cfg, **kw))(patches)


def images_to_patches(images: jnp.ndarray, cfg: VitConfig) -> jnp.ndarray:
    """(B, H, W, C) → (B, N_p, C·P²): the Fig. 4 conv→FC data conversion."""
    b, h, w, c = images.shape
    p = cfg.patch_size
    x = images.reshape(b, h // p, p, w // p, p, c)
    x = jnp.transpose(x, (0, 1, 3, 5, 2, 4))  # (B, hp, wp, C, p, p)
    return x.reshape(b, cfg.num_patches, c * p * p)
