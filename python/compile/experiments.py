"""Software-side experiment driver: Tables 2, 3 and 4 of the paper.

Usage:  ``python -m compile.experiments table2|table3|table4|all``

Each experiment trains the scaled-down ViT on the synthetic dataset
(DESIGN.md §Substitutions) with the paper's three-stage QAT recipe and
prints our measured table next to the paper's published ImageNet numbers.
Results land in ``../artifacts/experiments/<table>.json`` for
EXPERIMENTS.md.
"""

from __future__ import annotations

import json
import os
import sys
import time

import jax.numpy as jnp
import numpy as np

from . import model as M
from .data import make_dataset
from .train import TrainConfig, three_stage_train

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "experiments")

# Reproduction-scale knobs: hard enough that quantization costs accuracy,
# small enough that the whole table trains in minutes.
NOISE = 1.2
TRAIN_PER_CLASS = 40
TEST_PER_CLASS = 25
EPOCHS = TrainConfig(epochs_pretrain=14, epochs_binary=14, epochs_act=8)


def _dataset(cfg: M.VitConfig, seed: int = 0):
    x, y = make_dataset(TRAIN_PER_CLASS, cfg.num_classes, cfg.image_size, seed=seed, noise=NOISE)
    xt, yt = make_dataset(TEST_PER_CLASS, cfg.num_classes, cfg.image_size, seed=seed + 1, noise=NOISE)
    return (
        (np.asarray(M.images_to_patches(jnp.asarray(x), cfg)), y),
        (np.asarray(M.images_to_patches(jnp.asarray(xt), cfg)), yt),
    )


def _save(name: str, payload: dict) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[saved {path}]")


def _model_size_bits(cfg: M.VitConfig, binary: bool) -> int:
    m, h = cfg.embed_dim, cfg.embed_dim * cfg.mlp_ratio
    enc = cfg.depth * (3 * m * m + m * m + m * h + h * m)
    rest = cfg.patch_in * m + cfg.tokens * m + m + m * cfg.num_classes
    return (enc * (1 if binary else 32)) + rest * 32


PAPER_TABLE2 = [
    ("DeiT-base (paper)", 81.8, "86M × 32"),
    ("T2T (paper)", 71.7, "4.7M × 32"),
    ("DeiT (paper)", 72.2, "5.7M × 32"),
    ("PiT (paper)", 73.0, "4.9M × 32"),
    ("Cross-ViT (paper)", 73.4, "6.9M × 32"),
    ("MobileViT (paper)", 74.8, "2.3M × 32"),
    ("Ours DeiT-base-W1A32 (paper)", 79.5, "86M × 1"),
    ("Ours DeiT-base-W1A8 (paper)", 77.6, "86M × 1"),
    ("Ours DeiT-base-W1A6 (paper)", 76.5, "86M × 1"),
]


def table2() -> dict:
    """Accuracy vs quantization regime (paper Table 2) at micro scale.

    Every regime gets the same total epoch budget (the paper trains each
    row to convergence), so rows differ only in quantization:
      * W32A32 — full budget at full precision;
      * W1A32  — pre-train + progressive binary (the remaining budget);
      * W1A{8,6} — the full three-stage recipe;
      * W1A2  — extension row: aggressive activation quantization, where
        the accuracy cliff reappears even at micro scale (at b ≥ 4 the
        micro model is insensitive; the paper's 86M-param model already
        loses 1.9 points at b=8).
    """
    cfg = M.micro_vit(embed_dim=24, depth=2, num_heads=4)
    ds = _dataset(cfg)
    rows = []
    total = EPOCHS.epochs_pretrain + EPOCHS.epochs_binary + EPOCHS.epochs_act

    t0 = time.time()
    # W32A32: full budget at stage 1 only.
    tc = TrainConfig(epochs_pretrain=total, epochs_binary=0, epochs_act=0)
    params, rs = three_stage_train(cfg, tc, dataset=ds, act_bits=None)
    rows.append(
        {"regime": "W32A32", "test_acc": rs[0].test_acc, "bits": _model_size_bits(cfg, False)}
    )
    # W1A32: pretrain + (binary gets the rest of the budget).
    tc = TrainConfig(
        epochs_pretrain=EPOCHS.epochs_pretrain,
        epochs_binary=total - EPOCHS.epochs_pretrain,
        epochs_act=0,
    )
    _, rs = three_stage_train(cfg, tc, dataset=ds, act_bits=None)
    rows.append(
        {"regime": "W1A32", "test_acc": rs[1].test_acc, "bits": _model_size_bits(cfg, True)}
    )
    for bits in (8, 6, 2):
        tc = TrainConfig(**{**EPOCHS.__dict__})
        _, rs = three_stage_train(cfg, tc, dataset=ds, act_bits=bits)
        rows.append(
            {
                "regime": f"W1A{bits}",
                "test_acc": rs[-1].test_acc,
                "bits": _model_size_bits(cfg, True),
            }
        )

    print("\nTable 2 (reproduction scale) — paper rows for reference")
    print(f"{'Method':<34} {'Acc (%)':>8}   Space")
    for name, acc, space in PAPER_TABLE2:
        print(f"{name:<34} {acc:>8.1f}   {space}")
    print("-" * 60)
    for r in rows:
        print(
            f"{'Ours micro-' + r['regime']:<34} {100 * r['test_acc']:>8.1f}   "
            f"{r['bits'] / 8e3:.1f} kB"
        )
    fp_bits = rows[0]["bits"]
    bin_bits = rows[1]["bits"]
    print(f"weight-space reduction: {fp_bits / bin_bits:.1f}× (paper: ~32× on encoder weights)")
    payload = {"rows": rows, "seconds": time.time() - t0, "paper": PAPER_TABLE2}
    _save("table2", payload)
    return payload


def table3() -> dict:
    """Small models are fragile under binarization (paper Table 3):
    the accuracy *drop* from W32A32 → W1A32 is larger for the smaller
    model."""
    t0 = time.time()
    rows = []
    total = EPOCHS.epochs_pretrain + EPOCHS.epochs_binary + EPOCHS.epochs_act
    for name, cfg in (
        ("micro-tiny", M.micro_vit(embed_dim=24, depth=2, num_heads=4)),
        ("micro-small", M.micro_vit(embed_dim=64, depth=2, num_heads=4)),
    ):
        ds = _dataset(cfg)
        # Equal budgets per regime, like Table 2: the W32A32 row gets the
        # full budget at full precision, the W1A32 row splits it.
        tc32 = TrainConfig(epochs_pretrain=total, epochs_binary=0, epochs_act=0)
        _, rs32 = three_stage_train(cfg, tc32, dataset=ds, act_bits=None)
        tcb = TrainConfig(
            epochs_pretrain=EPOCHS.epochs_pretrain,
            epochs_binary=total - EPOCHS.epochs_pretrain,
            epochs_act=0,
        )
        _, rsb = three_stage_train(cfg, tcb, dataset=ds, act_bits=None)
        rows.append(
            {
                "model": name,
                "w32a32": rs32[0].test_acc,
                "w1a32": rsb[1].test_acc,
                "drop": rs32[0].test_acc - rsb[1].test_acc,
            }
        )
    print("\nTable 3 (reproduction scale) — paper: tiny 72.2→51.5, small 79.9→70.4")
    print(f"{'Model':<14} {'W32A32':>8} {'W1A32':>8} {'drop':>7}")
    for r in rows:
        print(
            f"{r['model']:<14} {100 * r['w32a32']:>8.1f} {100 * r['w1a32']:>8.1f} "
            f"{100 * r['drop']:>7.1f}"
        )
    payload = {"rows": rows, "seconds": time.time() - t0}
    _save("table3", payload)
    return payload


def table4() -> dict:
    """Training-schedule ablation (paper Table 4): full recipe vs
    w/o pre-training vs w/o progressive binarization."""
    cfg = M.micro_vit(embed_dim=32, depth=2, num_heads=4)
    ds = _dataset(cfg)
    t0 = time.time()
    rows = []
    for name, pretrain, progressive in (
        ("W1A32 (full recipe)", True, True),
        ("W1A32 w/o pre-training", False, True),
        ("W1A32 w/o progressive", True, False),
    ):
        tc = TrainConfig(**{**EPOCHS.__dict__})
        tc.pretrain = pretrain
        tc.progressive = progressive
        if not pretrain:
            # Keep the total step budget comparable (paper trains the same
            # number of epochs per stage).
            tc.epochs_binary = EPOCHS.epochs_binary + EPOCHS.epochs_pretrain
        _, rs = three_stage_train(cfg, tc, dataset=ds, act_bits=None)
        rows.append({"method": name, "test_acc": rs[-1].test_acc})
    print("\nTable 4 (reproduction scale) — paper: 84.3 / 79.3 / 78.4 on ImageNet-100")
    for r in rows:
        print(f"{r['method']:<28} {100 * r['test_acc']:>6.1f}")
    payload = {"rows": rows, "seconds": time.time() - t0}
    _save("table4", payload)
    return payload


def main() -> None:
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("table2", "all"):
        table2()
    if which in ("table3", "all"):
        table3()
    if which in ("table4", "all"):
        table4()


if __name__ == "__main__":
    main()
