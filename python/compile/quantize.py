"""ViT quantization (paper §4.2) — the software half of VAQF.

* :func:`binarize` — Eq. 5: ``w_b = (‖W‖₁/n)·sign(w)`` (zero → −scale).
* :func:`fake_quant_act` — uniform symmetric b-bit activation
  fake-quantization with dynamic max-abs calibration (the QAT forward
  pass; the straight-through estimator comes for free under
  ``jax.lax.stop_gradient`` composition in :func:`ste_quant_act`).
* :class:`ProgressiveMask` — Eq. 6 progressive binarization (identical
  element order as ``rust/src/quant/progressive.rs`` for a given seed).

The Rust accelerator executes the *integer* equivalents of these; this
module is their f32 functional mirror used for training and AOT export.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .prng import SplitMix64


def binarize(w: jnp.ndarray) -> jnp.ndarray:
    """Eq. 5: per-matrix ℓ1 scale times sign. ``sign(0) → −1`` (paper's
    convention: ``w_r > 0 → +scale`` else ``−scale``)."""
    scale = jnp.mean(jnp.abs(w))
    return jnp.where(w > 0, scale, -scale)


def binary_scale(w: jnp.ndarray) -> jnp.ndarray:
    """The ℓ1/n scaling factor of Eq. 5."""
    return jnp.mean(jnp.abs(w))


def qmax_for(bits: int) -> int:
    return max((1 << (bits - 1)) - 1, 1)


def fake_quant_act(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quantize-dequantize activations to ``bits`` with dynamic per-tensor
    max-abs calibration (mirrors ``rust/src/quant/activation.rs``)."""
    if bits >= 32:
        return x
    if bits == 1:
        scale = jnp.mean(jnp.abs(x))
        return jnp.where(x > 0, scale, -scale)
    qmax = qmax_for(bits)
    max_abs = jnp.max(jnp.abs(x))
    scale = jnp.where(max_abs > 0, max_abs / qmax, 1.0)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    return q * scale


def ste_quant_act(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Straight-through-estimator activation quantization for QAT: the
    forward value is the quantized one, the gradient passes through."""
    return x + jax.lax.stop_gradient(fake_quant_act(x, bits) - x)


def ste_binarize(w: jnp.ndarray) -> jnp.ndarray:
    """STE weight binarization (XNOR-Net-style)."""
    return w + jax.lax.stop_gradient(binarize(w) - w)


class ProgressiveMask:
    """Eq. 6 progressive binarization mask.

    Element order is a seeded Fisher–Yates shuffle identical to the Rust
    implementation, so a (seed, fraction) pair selects the same weights on
    both sides.
    """

    def __init__(self, n: int, seed: int) -> None:
        order = list(range(n))
        SplitMix64(seed).shuffle(order)
        self.order = np.asarray(order, dtype=np.int64)
        self.n = n
        self.binarized = 0

    def set_fraction(self, p: float) -> None:
        target = int(round(self.n * min(max(p, 0.0), 1.0)))
        self.binarized = max(self.binarized, min(target, self.n))

    def dense(self) -> np.ndarray:
        m = np.zeros(self.n, dtype=bool)
        m[self.order[: self.binarized]] = True
        return m

    def blend(self, real: jnp.ndarray, binary: jnp.ndarray) -> jnp.ndarray:
        """W_p = M_p·W_b + (1−M_p)·W_r (Eq. 6)."""
        mask = jnp.asarray(self.dense().reshape(real.shape))
        return jnp.where(mask, binary, real)


def progressive_schedule(epoch: int, total_epochs: int) -> float:
    """Linear 0 → 1 over training (paper §4.2)."""
    if total_epochs <= 1:
        return 1.0
    return min(max(epoch / (total_epochs - 1), 0.0), 1.0)
