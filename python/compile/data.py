"""Synthetic inputs & datasets.

* :func:`synthetic_patches` — bit-exact mirror of
  ``rust/src/sim/weights.rs::VitWeights::synthetic_patches`` (same PRNG
  stream), used by the sim↔runtime cross-check.
* :func:`make_dataset` — the structured 10-class image dataset replacing
  ImageNet for the Table 2–4 accuracy experiments (DESIGN.md
  §Substitutions): each class is a distinct 2-D frequency grating whose
  phase/orientation jitters per sample, plus noise. Linear probes cannot
  solve it from raw pixels at high noise; a small ViT can.
"""

from __future__ import annotations

import numpy as np

from .model import VitConfig
from .prng import SplitMix64


def synthetic_patches(cfg: VitConfig, seed: int, frame_id: int) -> np.ndarray:
    """(N_p, 3P²) uniform[-1,1) patches from the shared PRNG stream."""
    n = cfg.num_patches * cfg.patch_in
    rng = SplitMix64(seed ^ 0x5EED_F00D ^ ((frame_id * 0x9E37) & ((1 << 64) - 1)))
    out = np.empty(n, dtype=np.float32)
    for i in range(n):
        out[i] = rng.next_f32_range(-1.0, 1.0)
    return out.reshape(cfg.num_patches, cfg.patch_in)


def make_dataset(
    n_per_class: int,
    num_classes: int,
    image_size: int,
    seed: int,
    noise: float = 0.35,
) -> tuple[np.ndarray, np.ndarray]:
    """Structured classification data: (images (N,H,W,3), labels (N,))."""
    rng = np.random.default_rng(seed)
    h = w = image_size
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32) / image_size
    images = []
    labels = []
    for c in range(num_classes):
        freq = 1.5 + 1.1 * c
        theta = np.pi * c / num_classes
        for _ in range(n_per_class):
            phase = rng.uniform(0, 2 * np.pi)
            jitter = rng.uniform(-0.15, 0.15)
            g = np.sin(
                2 * np.pi * freq * (np.cos(theta + jitter) * xx + np.sin(theta + jitter) * yy)
                + phase
            )
            img = np.stack(
                [
                    g,
                    np.roll(g, c + 1, axis=0),
                    -g * (0.5 + 0.05 * c),
                ],
                axis=-1,
            ).astype(np.float32)
            img += rng.normal(0, noise, img.shape).astype(np.float32)
            images.append(img)
            labels.append(c)
    images = np.stack(images)
    labels = np.asarray(labels, dtype=np.int32)
    perm = rng.permutation(len(labels))
    return images[perm], labels[perm]
