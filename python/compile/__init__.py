"""VAQF build-time Python stack (L1 Pallas kernels + L2 JAX model + AOT).

Never imported at runtime: the Rust binary consumes only the HLO-text
artifacts this package emits via ``python -m compile.aot``.
"""
