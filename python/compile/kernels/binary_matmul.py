"""L1 Pallas kernel: binary-weight quantized matmul.

The paper's compute hot-spot is a binary-weight × b-bit-activation matrix
multiply executed as LUT additions/subtractions on the FPGA. The TPU
rethink (DESIGN.md §Hardware-Adaptation): keep the weights as a dense
{−1,+1} sign matrix so the MXU runs a *regular* matmul over sign values,
keep the activations on their integer grid (quantized on the VPU inside
the kernel), and hoist both scales out of the inner loop — one multiply
per output element, exactly like the paper hoists the ℓ1 scale out of the
LUT array.

Tiling: the grid walks (F/bf, M/bm) output blocks; each block streams the
full K dimension through VMEM. On a real TPU the BlockSpec index maps
below express the HBM→VMEM schedule the paper expressed with DDR→BRAM
loop tiling; under ``interpret=True`` (mandatory on CPU — Mosaic
custom-calls cannot execute here) the same index maps drive a NumPy
evaluator, so correctness of the schedule is still exercised.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is ≤ preferred (MXU-friendly when
    possible, but always exact so interpret-mode shapes stay static)."""
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return max(b, 1)


def _kernel(x_ref, w_ref, out_ref, *, qmax: float, inv_scale_ref, scale_ref):
    """One (bf × bm) output block: quantize activations, MXU matmul over
    sign weights, single fused dequantization multiply."""
    x = x_ref[...]
    # VPU: snap activations to their integer grid (values stay in f32 —
    # integers up to qmax·K are exact in f32 for every supported b ≤ 16).
    q = jnp.clip(jnp.round(x * inv_scale_ref[0]), -qmax - 1, qmax)
    # MXU: dense matmul over {−1,+1} signs.
    acc = q @ w_ref[...]
    # Fused epilogue: act_scale · w_scale.
    out_ref[...] = acc * scale_ref[0]


@functools.partial(jax.jit, static_argnames=("bits", "block_f", "block_m"))
def binary_matmul(
    x: jnp.ndarray,
    w_signs: jnp.ndarray,
    w_scale: jnp.ndarray,
    bits: int,
    block_f: int = 128,
    block_m: int = 128,
) -> jnp.ndarray:
    """Quantized binary-weight matmul: ``fq_b(x) @ (±1 signs) · w_scale``.

    x: (F, N) f32; w_signs: (N, M) in {−1,+1}; w_scale: scalar.
    Activation scale is dynamic per-tensor max-abs (computed outside the
    kernel — a global reduction), matching the oracle in ``ref.py`` and the
    Rust integer datapath bit-for-bit in exact arithmetic.
    """
    f, n = x.shape
    n2, m = w_signs.shape
    assert n == n2, (x.shape, w_signs.shape)
    qmax = float(max((1 << (bits - 1)) - 1, 1))

    max_abs = jnp.max(jnp.abs(x))
    act_scale = jnp.where(max_abs > 0, max_abs / qmax, 1.0)
    inv_scale = jnp.where(max_abs > 0, qmax / max_abs, 1.0)

    if bits == 1:
        # Binary activations are a sign function, not a uniform grid.
        xq = jnp.where(x > 0, 1.0, -1.0)
        scale = jnp.mean(jnp.abs(x)) * w_scale
        return (xq @ w_signs) * scale

    bf = _pick_block(f, block_f)
    bm = _pick_block(m, block_m)

    kernel = functools.partial(_kernel, qmax=qmax)
    out = pl.pallas_call(
        lambda inv_ref, sc_ref, x_ref, w_ref, o_ref: kernel(
            x_ref, w_ref, o_ref, inv_scale_ref=inv_ref, scale_ref=sc_ref
        ),
        grid=(f // bf, m // bm),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bf, n), lambda i, j: (i, 0)),
            pl.BlockSpec((n, bm), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bf, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((f, m), x.dtype),
        interpret=True,
    )(
        inv_scale.reshape(1),
        (act_scale * w_scale).reshape(1),
        x,
        w_signs.astype(x.dtype),
    )
    return out


def vmem_bytes_estimate(f: int, n: int, m: int, block_f: int = 128, block_m: int = 128) -> int:
    """VMEM footprint of one grid step (f32): x block + w block + out block.

    Used by DESIGN.md §Perf to check the double-buffered footprint fits a
    TPU core's ~16 MiB VMEM — the analogue of the paper's Eq. 12 BRAM
    bound.
    """
    bf = _pick_block(f, block_f)
    bm = _pick_block(m, block_m)
    return 4 * (bf * n + n * bm + bf * bm) * 2  # ×2 double buffering
