"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package must match its reference here to float
tolerance under ``interpret=True``; ``python/tests/test_kernel.py`` sweeps
shapes/precisions (hypothesis) and asserts allclose.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..quantize import fake_quant_act, qmax_for


def binary_matmul_ref(
    x: jnp.ndarray, w_signs: jnp.ndarray, w_scale: jnp.ndarray, bits: int
) -> jnp.ndarray:
    """Reference binary-weight quantized matmul.

    ``x``: (F, N) activations; ``w_signs``: (N, M) in {−1, +1};
    ``w_scale``: scalar ℓ1/n factor; activations fake-quantized to
    ``bits`` with dynamic max-abs calibration.
    """
    xq = fake_quant_act(x, bits)
    return (xq @ w_signs) * w_scale


def qq_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Reference quantized×quantized matmul (attention operands)."""
    return fake_quant_act(a, bits) @ fake_quant_act(b, bits)


def _softmax(x: jnp.ndarray) -> jnp.ndarray:
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def quant_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, bits: int
) -> jnp.ndarray:
    """Reference single-head quantized attention.

    ``q``/``k``/``v``: (F, M_h). Scaling by 1/sqrt(M_h) after Q·Kᵀ, then
    softmax, re-quantization of S, and S·V — exactly the on-host /
    on-fabric split of paper §5.2.
    """
    mh = q.shape[-1]
    s = qq_matmul_ref(q, jnp.swapaxes(k, -1, -2), bits) / jnp.sqrt(
        jnp.asarray(mh, dtype=q.dtype)
    )
    return qq_matmul_ref(_softmax(s), v, bits)


def act_quant_error_bound(x: jnp.ndarray, bits: int) -> float:
    """Worst-case elementwise fake-quantization error (half a step)."""
    if bits >= 32:
        return 0.0
    qmax = qmax_for(bits)
    max_abs = float(jnp.max(jnp.abs(x)))
    scale = max_abs / qmax if max_abs > 0 else 1.0
    return scale / 2 + 1e-7
