"""L1 Pallas kernel: quantized multi-head attention block.

Fuses the paper's attention-layer sequence — quantized Q·Kᵀ, host-side
1/√d scaling + softmax, re-quantization, quantized S·V — into one kernel
gridded over heads (the P_h dimension of the paper's compute engine maps
onto the Pallas grid).

Quantization scales are *per-head dynamic max-abs*, matching both the
pure-jnp oracle (``ref.quant_attention_ref`` vmapped over heads) and the
per-head calibration the Rust simulator performs in
``sim::engine::qq_matmul``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fq(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    qmax = float(max((1 << (bits - 1)) - 1, 1))
    max_abs = jnp.max(jnp.abs(x))
    scale = jnp.where(max_abs > 0, max_abs / qmax, 1.0)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    return q * scale


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, bits: int):
    """One head: everything in VMEM (F ≤ a few hundred for ViT)."""
    q = _fq(q_ref[0], bits)
    k = _fq(k_ref[0], bits)
    v = _fq(v_ref[0], bits)
    mh = q.shape[-1]
    s = (q @ k.T) / jnp.sqrt(jnp.asarray(mh, dtype=q.dtype))
    # Softmax (numerically-stable) — the "host" op, fused here since the
    # TPU has no separate host; the quantization boundary is preserved.
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = _fq(p, bits) @ v


@functools.partial(jax.jit, static_argnames=("bits",))
def quant_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, bits: int
) -> jnp.ndarray:
    """Quantized attention over heads.

    q/k/v: (H, F, M_h) → (H, F, M_h).
    """
    h, f, mh = q.shape
    return pl.pallas_call(
        functools.partial(_attn_kernel, bits=bits),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, f, mh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, f, mh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, f, mh), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, f, mh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, f, mh), q.dtype),
        interpret=True,
    )(q, k, v)
