"""Pallas kernels (L1) + pure-jnp oracles."""

from .attention import quant_attention
from .binary_matmul import binary_matmul, vmem_bytes_estimate
from . import ref

__all__ = ["binary_matmul", "quant_attention", "vmem_bytes_estimate", "ref"]
