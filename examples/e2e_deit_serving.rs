//! END-TO-END driver: proves all three layers compose.
//!
//! 1. Loads the AOT artifacts (`make artifacts`): the L2 JAX model with
//!    the L1 Pallas binary-matmul/attention kernels lowered into HLO text,
//!    compiles them on the PJRT CPU client (the Rust runtime — no Python
//!    anywhere on this path).
//! 2. Runs the VAQF compiler (L3) for the micro model on the simulated
//!    ZCU102 and instantiates the cycle-level accelerator simulator with
//!    the chosen parameters.
//! 3. **Cross-checks** the simulator's functional logits against the PJRT
//!    runtime's logits frame by frame (identical weights via the shared
//!    SplitMix64 stream) — the numerical proof that the Rust integer
//!    datapath computes the same function the JAX/Pallas model defines.
//! 4. Serves a batched request stream through both backends and reports
//!    latency/throughput (recorded in EXPERIMENTS.md §E2E).
//!
//! Run with: `make artifacts && cargo run --release --example e2e_deit_serving`

use vaqf::compiler::{compile, CompileRequest};
use vaqf::coordinator::{serve, FrameSource, ServeConfig};
use vaqf::hw::zcu102;
use vaqf::runtime::{InferenceEngine, Manifest, PjrtBackend, SimBackend};
use vaqf::sim::{generate_weights, ModelExecutor};
use vaqf::util::stats::Summary;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("=== VAQF end-to-end: AOT artifacts → PJRT runtime ⇄ FPGA simulator ===\n");

    // ---- 1. load artifacts ------------------------------------------------
    let man = Manifest::load(&artifacts)?;
    let mut engine = InferenceEngine::new()?;
    for v in &man.variants {
        engine.load_variant(v)?;
        println!("loaded {} ({} params, HLO {})", v.tag, v.param_count, v.hlo_path.display());
    }
    println!("PJRT platform: {}\n", engine.platform());

    // ---- 2. compile an accelerator for the micro model --------------------
    let entry = man
        .find("micro_w1a8")
        .ok_or_else(|| anyhow::anyhow!("micro_w1a8 missing from manifest"))?;
    let device = zcu102();
    let request = CompileRequest {
        model: entry.config.clone(),
        device: device.clone(),
        // The micro model is tiny; ask for a high-rate camera.
        target_fps: 1000.0,
    };
    let outcome = compile(&request)?;
    println!(
        "compiled accelerator: W1A{} predicted {:.0} FPS on {} (T_m^q={}, G^q={})\n",
        outcome.act_bits,
        outcome.design.summary.fps,
        device.name,
        outcome.design.params.t_m_q,
        outcome.design.params.g_q
    );

    // The artifact precision is fixed at 8-bit; build the simulator with
    // the corresponding design point (re-optimized at exactly 8 bits).
    let base = vaqf::compiler::optimize_baseline(&entry.config.structure(None), &device);
    let design8 =
        vaqf::compiler::optimize_for_bits(&entry.config.structure(Some(8)), &base, &device, 8)?;
    let weights = generate_weights(&entry.config, entry.seed);
    let executor = ModelExecutor::new(weights.clone(), Some(8), design8.params, device.clone());

    // ---- 3. numerical cross-check: sim vs PJRT ---------------------------
    println!("--- cross-check: simulator (integer datapath) vs PJRT (JAX/Pallas HLO) ---");
    let mut max_rel = 0.0f64;
    let mut agree = 0usize;
    const FRAMES: u64 = 8;
    for fid in 0..FRAMES {
        let patches = weights.synthetic_patches(fid);
        let (sim_logits, _) = executor.run_frame(&patches);
        let pjrt_logits = engine.infer("micro_w1a8", &patches)?;
        let scale = pjrt_logits
            .iter()
            .fold(0.0f32, |m, v| m.max(v.abs()))
            .max(1e-6);
        let rel = sim_logits
            .iter()
            .zip(&pjrt_logits)
            .map(|(a, b)| ((a - b).abs() / scale) as f64)
            .fold(0.0, f64::max);
        max_rel = max_rel.max(rel);
        let argmax = |v: &[f32]| {
            v.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        };
        let same = argmax(&sim_logits) == argmax(&pjrt_logits);
        agree += same as usize;
        println!(
            "frame {fid}: max rel err {rel:.4}  top-1 {} ({})",
            argmax(&pjrt_logits),
            if same { "match" } else { "MISMATCH" }
        );
    }
    println!(
        "cross-check: {agree}/{FRAMES} top-1 agreement, max relative error {max_rel:.4}\n"
    );
    anyhow::ensure!(
        max_rel < 0.05,
        "simulator and PJRT runtime disagree beyond fixed-point tolerance"
    );
    anyhow::ensure!(agree as u64 == FRAMES, "top-1 disagreement");

    // ---- 4. serve batched requests through both backends ------------------
    println!("--- serving 120 frames @ 200 FPS offered ---");
    let serve_cfg = ServeConfig {
        offered_fps: 200.0,
        frames: 120,
        queue_depth: 4,
        source_seed: man.seed,
    };

    let source = FrameSource::new(entry.config.clone(), man.seed, Some(serve_cfg.offered_fps));
    let pjrt_report = serve(
        source,
        Box::new(PjrtBackend {
            engine: std::rc::Rc::new(engine),
            tag: "micro_w1a8".into(),
        }),
        &serve_cfg,
    )?;
    println!("{}", pjrt_report.render());

    let source = FrameSource::new(entry.config.clone(), man.seed, Some(serve_cfg.offered_fps));
    let sim_report = serve(
        source,
        Box::new(SimBackend {
            executor,
            realtime: false,
        }),
        &serve_cfg,
    )?;
    println!("{}", sim_report.render());

    // Simulated-FPGA frame rate for the compiled design (what the board
    // would sustain at 150 MHz):
    let sim_fps: Vec<f64> = (0..4)
        .map(|i| {
            let exec = ModelExecutor::new(
                weights.clone(),
                Some(8),
                design8.params,
                device.clone(),
            );
            let (_, t) = exec.run_frame(&weights.synthetic_patches(i));
            t.fps()
        })
        .collect();
    let s = Summary::from(&sim_fps);
    println!(
        "simulated accelerator sustained rate: {:.0} FPS (design prediction {:.0} FPS)",
        s.mean, design8.summary.fps
    );
    println!("\nE2E OK — all layers compose.");
    Ok(())
}
