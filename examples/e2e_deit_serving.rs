//! END-TO-END driver: proves all three layers compose — through `vaqf::api`.
//!
//! 1. Loads the AOT artifacts (`make artifacts`): the L2 JAX model with
//!    the L1 Pallas binary-matmul/attention kernels lowered into HLO text,
//!    compiled on the PJRT CPU client (`api::PjrtRuntime` — no Python
//!    anywhere on this path).
//! 2. Runs the VAQF compiler (L3) for the micro model on the simulated
//!    ZCU102 and instantiates the cycle-level accelerator simulator with
//!    the chosen parameters (`CompiledDesign::simulator_with_seed`).
//! 3. **Cross-checks** the simulator's functional logits against the PJRT
//!    runtime's logits frame by frame (identical weights via the shared
//!    SplitMix64 stream) — the numerical proof that the Rust integer
//!    datapath computes the same function the JAX/Pallas model defines.
//! 4. Serves a batched request stream through both backends
//!    (`CompiledDesign::server`) and reports latency/throughput (recorded
//!    in EXPERIMENTS.md §E2E).
//!
//! Run with: `make artifacts && cargo run --release --example e2e_deit_serving`

use vaqf::api::{PjrtRuntime, Result, ServeConfig, TargetSpec, VaqfError};
use vaqf::util::stats::Summary;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("=== VAQF end-to-end: AOT artifacts → PJRT runtime ⇄ FPGA simulator ===\n");

    // ---- 1. load artifacts ------------------------------------------------
    let runtime = PjrtRuntime::load(&artifacts)?;
    for v in &runtime.manifest().variants {
        println!("loaded {} ({} params, HLO {})", v.tag, v.param_count, v.hlo_path.display());
    }
    println!("PJRT platform: {}\n", runtime.platform());

    // ---- 2. compile an accelerator for the micro model --------------------
    let entry = runtime
        .manifest()
        .find("micro_w1a8")
        .ok_or_else(|| VaqfError::config("micro_w1a8 missing from manifest"))?;
    let session = TargetSpec::new()
        .model(entry.config.clone())
        .device_preset("zcu102")
        // The micro model is tiny; ask for a high-rate camera.
        .target_fps(1000.0)
        .session()?;
    let compiled = session.compile()?;
    println!(
        "compiled accelerator: W1A{} predicted {:.0} FPS on {} (T_m^q={}, G^q={})\n",
        compiled.act_bits().unwrap_or(16),
        compiled.summary().fps,
        session.target().device.name,
        compiled.params().t_m_q,
        compiled.params().g_q
    );

    // The artifact precision is fixed at 8-bit; build the simulator with
    // the corresponding design point (re-optimized at exactly 8 bits).
    let design8 = session.compile_for_bits(Some(8))?;
    let mut executor = design8.simulator_with_seed(entry.seed);

    // ---- 3. numerical cross-check: sim vs PJRT ---------------------------
    println!("--- cross-check: simulator (integer datapath) vs PJRT (JAX/Pallas HLO) ---");
    let mut max_rel = 0.0f64;
    let mut agree = 0usize;
    const FRAMES: u64 = 8;
    for fid in 0..FRAMES {
        let patches = executor.weights().synthetic_patches(fid);
        let (sim_logits, _) = executor.run_frame(&patches);
        let pjrt_logits = runtime.infer("micro_w1a8", &patches)?;
        let scale = pjrt_logits
            .iter()
            .fold(0.0f32, |m, v| m.max(v.abs()))
            .max(1e-6);
        let rel = sim_logits
            .iter()
            .zip(&pjrt_logits)
            .map(|(a, b)| ((a - b).abs() / scale) as f64)
            .fold(0.0, f64::max);
        max_rel = max_rel.max(rel);
        let argmax = |v: &[f32]| {
            v.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap()
        };
        let same = argmax(&sim_logits) == argmax(&pjrt_logits);
        agree += same as usize;
        println!(
            "frame {fid}: max rel err {rel:.4}  top-1 {} ({})",
            argmax(&pjrt_logits),
            if same { "match" } else { "MISMATCH" }
        );
    }
    println!("cross-check: {agree}/{FRAMES} top-1 agreement, max relative error {max_rel:.4}\n");
    if max_rel >= 0.05 {
        return Err(VaqfError::runtime(anyhow::anyhow!(
            "simulator and PJRT runtime disagree beyond fixed-point tolerance"
        )));
    }
    if agree as u64 != FRAMES {
        return Err(VaqfError::runtime(anyhow::anyhow!("top-1 disagreement")));
    }

    // ---- 4. serve batched requests through both backends ------------------
    println!("--- serving 120 frames @ 200 FPS offered ---");

    // Reuses the engine compiled in step 1 — no second XLA compilation.
    let pjrt_report = runtime.server(
        "micro_w1a8",
        &ServeConfig {
            offered_fps: 200.0,
            frames: 120,
            queue_depth: 4,
            source_seed: runtime.manifest().seed,
        },
    )?;
    println!("{}", pjrt_report.render());

    let sim_report = design8
        .server()
        .simulated(false)
        .offered_fps(200.0)
        .frames(120)
        .queue_depth(4)
        .source_seed(runtime.manifest().seed)
        .weights_seed(entry.seed)
        .run()?;
    println!("{}", sim_report.render());

    // Simulated-FPGA frame rate for the compiled design (what the board
    // would sustain at 150 MHz), reusing the step-3 executor:
    let sim_fps: Vec<f64> = (0..4)
        .map(|i| {
            let patches = executor.weights().synthetic_patches(i);
            let (_, t) = executor.run_frame(&patches);
            t.fps()
        })
        .collect();
    let s = Summary::from(&sim_fps);
    println!(
        "simulated accelerator sustained rate: {:.0} FPS (design prediction {:.0} FPS)",
        s.mean,
        design8.summary().fps
    );
    println!("\nE2E OK — all layers compose.");
    Ok(())
}
