//! Fleet deployment walkthrough: one ViT design, four boards, three ways
//! to spend them.
//!
//! 1. Compile DeiT-base for the ZCU102 at the paper's 24 FPS target.
//! 2. Carve a 4-board budget three ways — `replicated` (4 independent
//!    replicas), `pipelined` (one 4-stage shard pipeline), `mixed` (2
//!    replicas + a 2-board pipeline) — and replay the *same* Poisson
//!    trace through each on the virtual clock, comparing throughput,
//!    tail latency and per-unit utilization at equal board count.
//! 3. Stress the mixed fleet: an SLA-weighted balancer under a
//!    flash-crowd trace with a mid-burst crash, one hot spare, and a
//!    latency SLA — the fleet sheds, fails over, and recovers, with
//!    every frame accounted for (`offered == completed + dropped +
//!    failed`).
//!
//! Run with: `cargo run --release --example fleet_deploy`

use vaqf::api::{FaultPlan, RecoveryConfig, Result, TargetSpec, TraceSpec};

fn main() -> Result<()> {
    println!("=== fleet deployment: DeiT-base, 4 boards, 3 topologies ===\n");
    let design = TargetSpec::new()
        .model_preset("deit-base")
        .device_preset("zcu102")
        .target_fps(24.0)
        .session()?
        .compile()?;
    let single_fps = 1.0 / design.frame_latency_s();
    println!(
        "single board: {} at {:.1} FPS\n",
        design.summary().label,
        single_fps
    );

    // Offer 80% of the replicated fleet's aggregate capacity — loaded,
    // not saturated — through every topology at equal board count.
    let trace = TraceSpec::poisson(0.8 * 4.0 * single_fps, 2.0, 42);
    for topology in ["replicated", "pipelined", "mixed"] {
        let report = design
            .fleet()
            .boards(4)
            .topology(topology)
            .balancer("least-outstanding")
            .trace(trace.clone())
            .run()?;
        print!("{}\n", report.render());
    }

    println!("=== flash crowd + mid-burst crash on the mixed fleet ===\n");
    let burst = TraceSpec::flash_crowd(
        0.5 * single_fps, // quiet baseline
        6.0 * single_fps, // burst peak: beyond what 4 boards serve
        0.6,              // burst starts at t = 0.6 s
        0.1,              // ramp
        0.4,              // hold
        2.0,              // horizon
        7,
    );
    // Crash replica 0 mid-burst; one spare hot-swaps it back.
    let plan = FaultPlan::new().crash_at(0.8, 0).recovery(RecoveryConfig {
        spares: 1,
        ..RecoveryConfig::default()
    });
    let report = design
        .fleet()
        .boards(4)
        .topology("mixed")
        .balancer("sla-weighted")
        .trace(burst)
        .sla_ms(4.0 * 1e3 * design.frame_latency_s())
        .faults(plan)
        .run()?;
    print!("{}", report.render());

    let a = &report.aggregate;
    assert_eq!(
        a.offered,
        a.completed + a.dropped + a.failed,
        "fleet accounting must conserve frames"
    );
    println!(
        "\nconservation holds: {} offered == {} completed + {} dropped + {} failed",
        a.offered, a.completed, a.dropped, a.failed
    );
    Ok(())
}
