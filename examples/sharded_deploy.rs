//! Sharded deployment walkthrough: one ViT, N pipelined accelerators.
//!
//! 1. Compile DeiT-base for the ZCU102 at the paper's 24 FPS target.
//! 2. Partition the compiled design across 2 accelerator instances
//!    (balanced min-max over the per-layer cycle breakdown) and co-search
//!    each stage's parameters under the per-shard budget.
//! 3. Drive the discrete-event pipeline simulation on the virtual clock:
//!    steady-state throughput, fill, backpressure, latency percentiles.
//! 4. Functional cross-check on the micro model: push frames through the
//!    sharded cycle-level executors stage by stage and verify the logits
//!    are bit-identical to the unsharded simulator.
//!
//! Run with: `cargo run --release --example sharded_deploy`

use vaqf::api::{Backend, Result, TargetSpec};
use vaqf::shard::ShardedExecutor;

fn main() -> Result<()> {
    println!("=== sharded deployment: DeiT-base across 2 accelerator instances ===\n");
    let design = TargetSpec::new()
        .model_preset("deit-base")
        .device_preset("zcu102")
        .target_fps(24.0)
        .session()?
        .compile()?;
    println!(
        "unsharded: {} at {:.1} FPS ({} kcycles/frame)\n",
        design.summary().label,
        design.summary().fps,
        design.summary().cycles_per_frame / 1000
    );

    let sharded = design.shards(2)?;
    let report = sharded.report(240);
    print!("{}", report.render());

    println!("\n=== functional cross-check on the micro model ===\n");
    let micro = TargetSpec::new()
        .model(vaqf::model::micro())
        .device_preset("zcu102")
        .session()?
        .compile_for_bits(Some(8))?;
    let micro_sharded = micro.shards(2)?;
    let mut whole = micro.simulator_with_seed(11);
    let mut pipeline = ShardedExecutor::new(&micro_sharded, Backend::Packed, 0, 11);
    for frame in 0..3u64 {
        let patches = whole.weights().synthetic_patches(frame);
        let (expect, _) = whole.run_frame(&patches);
        let (got, trace) = pipeline.run_frame(&patches);
        assert_eq!(got, expect, "sharded logits diverged on frame {frame}");
        println!(
            "frame {frame}: logits bit-identical across {} stages ({} total kcycles)",
            trace.stages.len(),
            trace.total_cycles() / 1000
        );
    }
    println!("\nsharded functional path verified bit-exact against run_frame");
    Ok(())
}
