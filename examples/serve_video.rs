//! Real-time video serving on the simulated accelerator.
//!
//! Serves a synthetic 30 FPS camera stream through the cycle-level FPGA
//! simulator with wall-clock pacing (`realtime: true`), for each of the
//! three Table-5 precisions of the micro model — demonstrating the
//! paper's claim in serving terms: the W32A32 design sheds frames at
//! 30 FPS offered, the quantized designs keep up.
//!
//! Run with: `cargo run --release --example serve_video`

use vaqf::coordinator::{serve, FrameSource, ServeConfig};
use vaqf::hw::zcu102;
use vaqf::model::VitConfig;
use vaqf::perf::AcceleratorParams;
use vaqf::runtime::SimBackend;
use vaqf::sim::{generate_weights, ModelExecutor};

fn micro() -> VitConfig {
    VitConfig {
        name: "micro".into(),
        image_size: 32,
        patch_size: 8,
        in_chans: 3,
        embed_dim: 32,
        depth: 2,
        num_heads: 4,
        mlp_ratio: 4,
        num_classes: 10,
    }
}

fn params_for(bits: Option<u8>) -> AcceleratorParams {
    match bits {
        None => AcceleratorParams::baseline(8, 1, 4, 4), // deliberately lean: ~real-time limit
        Some(b) => {
            let g_q = AcceleratorParams::g_q_for(64, b);
            AcceleratorParams {
                t_m: 8,
                t_n: 1,
                t_m_q: 16,
                t_n_q: (g_q / 4).max(1),
                g: 4,
                g_q,
                p_h: 4,
                act_bits: Some(b),
            }
        }
    }
}

fn main() -> anyhow::Result<()> {
    println!("=== serving a synthetic 30 FPS camera through the simulated accelerator ===\n");
    let cfg = micro();
    let weights = generate_weights(&cfg, 11);

    for bits in [None, Some(8), Some(6)] {
        let label = match bits {
            None => "W32A32 (fixed16 baseline)".to_string(),
            Some(b) => format!("W1A{b}"),
        };
        let backend = SimBackend {
            executor: ModelExecutor::new(weights.clone(), bits, params_for(bits), zcu102()),
            realtime: true,
        };
        let serve_cfg = ServeConfig {
            offered_fps: 30.0,
            frames: 60,
            queue_depth: 2,
            source_seed: 11,
        };
        let source = FrameSource::new(cfg.clone(), 11, Some(serve_cfg.offered_fps));
        let report = serve(source, Box::new(backend), &serve_cfg)?;
        println!("--- {label} ---\n{}", report.render());
    }
    println!("(drop-oldest backpressure: a design slower than the offered rate sheds frames\n rather than growing latency — compare drop rates across precisions)");
    Ok(())
}
