//! Real-time video serving on the simulated accelerator.
//!
//! Serves a synthetic paced camera stream through the cycle-level FPGA
//! simulator with wall-clock pacing (`realtime: true`) for three compiled
//! micro-model designs (W32A32 / W1A8 / W1A6) on a deliberately small,
//! slow edge fabric — demonstrating the paper's claim in serving terms:
//! the camera is set to offer frames 1.5× faster than the unquantized
//! design can serve, so the W32A32 design sheds frames while the
//! quantized designs keep up.
//!
//! Unlike the pre-facade version of this example, the accelerator
//! parameters are *compiled* for the fabric (`Session::compile_for_bits`),
//! not hand-picked — the contrast between the designs is exactly what the
//! §5.3.2 optimizer produces.
//!
//! Run with: `cargo run --release --example serve_video`

use vaqf::api::{Device, Result, TargetSpec};
use vaqf::hw::ResourceBudget;
use vaqf::model::micro;

/// A camera-SoC-class fabric: a few MAC lanes and a slow clock, so
/// micro-ViT designs land in the tens-to-hundreds-of-FPS regime where
/// real-time pacing is observable (LUT/FF budgets keep the fixed control
/// overhead of the resource model feasible).
fn nano_edge() -> Device {
    Device {
        name: "nano-edge".into(),
        budget: ResourceBudget {
            dsp: 96,
            lut: 160_000,
            bram18k: 256,
            ff: 120_000,
        },
        clock_mhz: 2,
        axi_port_bits: 64,
        axi_ports_in: 1,
        axi_ports_wgt: 1,
        axi_ports_out: 1,
        r_dsp: 0.65,
        r_lut: 0.45,
        static_power_w: 0.8,
    }
}

fn main() -> Result<()> {
    println!("=== serving a synthetic camera through the simulated accelerator ===\n");
    let session = TargetSpec::new().model(micro()).device(nano_edge()).session()?;

    // Compile the three Table-5-style precisions for the same fabric.
    let designs = [
        session.compile_for_bits(None)?,
        session.compile_for_bits(Some(8))?,
        session.compile_for_bits(Some(6))?,
    ];
    for design in &designs {
        println!(
            "{:<8} predicted {:>7.1} FPS  (T_m={}, T_m^q={})",
            design.summary().label,
            design.summary().fps,
            design.params().t_m,
            design.params().t_m_q
        );
    }
    // Offer frames faster than the unquantized design can serve.
    let offered = designs[0].summary().fps * 1.5;
    println!("offered camera rate: {offered:.1} FPS\n");

    for design in &designs {
        let report = design
            .server()
            .simulated(true) // pace wall-clock to the simulated latency
            .offered_fps(offered)
            .frames(60)
            .queue_depth(2)
            .source_seed(11)
            .weights_seed(11)
            .run()?;
        println!("--- {} ---\n{}", design.summary().label, report.render());
    }
    println!(
        "(drop-oldest backpressure: a design slower than the offered rate sheds frames\n \
         rather than growing latency — compare drop rates across precisions)"
    );
    Ok(())
}
