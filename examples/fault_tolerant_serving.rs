//! Fault-tolerant serving: crashes, retries, hot spares and graceful
//! precision degradation — all on the deterministic virtual clock.
//!
//! Compiles one W1A8 micro-ViT design, then walks the fault subsystem
//! end to end:
//!
//! 1. a scripted crash/recover plan against a 2-worker pool — in-flight
//!    frames re-dispatch under the retry budget and the run reports
//!    availability and MTTR;
//! 2. a sustained throttle with a precision ladder attached — sustained
//!    SLA misses demote service down the ladder and recovery promotes
//!    back, instead of shedding frames;
//! 3. the same design sharded across two boards — a mid-run crash is
//!    absorbed by a hot spare (FIFO re-fill cost modeled), then by a
//!    live re-partition over the survivor.
//!
//! Every run here is byte-reproducible: rerun the example and every
//! number repeats exactly.
//!
//! Run with: `cargo run --release --example fault_tolerant_serving`

use vaqf::api::{FailoverStrategy, FaultPlan, RecoveryConfig, Result, TargetSpec};

fn main() -> Result<()> {
    println!("=== fault-tolerant serving: crash, degrade, fail over ===\n");
    let session = TargetSpec::new()
        .model(vaqf::model::micro())
        .device_preset("zcu102")
        .session()?;
    let design = session.compile_for_bits(Some(8))?;
    let base = design.frame_latency_s();
    println!(
        "compiled {}: predicted {:.0} FPS per accelerator instance\n",
        design.summary().label,
        design.summary().fps
    );

    // -- 1. crash and recover under retries ----------------------------------
    println!("--- worker 1 crashes at t=10ms, recovers at t=60ms ---");
    let plan = FaultPlan::new()
        .crash_at(0.010, 1)
        .recover_at(0.060, 1)
        .recovery(RecoveryConfig {
            max_retries: 3,
            ..Default::default()
        });
    let report = design
        .server()
        .streams(2)
        .workers(2)
        .policy("least-loaded")
        .offered_fps(150.0)
        .frames(40)
        .queue_depth(4)
        .sla_ms(base * 3.0 * 1e3)
        .analytic()
        .virtual_clock()
        .faults(plan)
        .run()?;
    println!("{}", report.render());

    // -- 2. graceful degradation through the precision ladder ----------------
    println!("--- 4x throttle with a W1A8 → W1A6 → W1A4 degrade ladder ---");
    let ladder = session.precision_ladder(&[8, 6, 4])?;
    let report = design
        .server()
        .streams(2)
        .workers(1)
        .offered_fps(0.5 / base)
        .frames(80)
        .queue_depth(2)
        .sla_ms(base * 2.0 * 1e3)
        .analytic()
        .virtual_clock()
        .faults(FaultPlan::new().slow_down_at(base * 2.0, 0, 4.0))
        .degrade_ladder(ladder)
        .run()?;
    println!("{}", report.render());

    // -- 3. sharded pipeline failover ----------------------------------------
    let sharded = design.shards(2)?;
    for (strategy, spares) in [
        (FailoverStrategy::Spare, 1usize),
        (FailoverStrategy::Repartition, 0),
    ] {
        println!("--- 2-shard pipeline, board 0 crashes: {strategy:?} failover ---");
        let plan = FaultPlan::new()
            .crash_at(5.0 * base, 0)
            .recovery(RecoveryConfig {
                spares,
                swap_s: base,
                reconfig_s: 4.0 * base,
                ..Default::default()
            });
        let report = sharded
            .report_with_faults(64, &plan, strategy)
            .map_err(vaqf::api::VaqfError::runtime)?;
        println!("{}", report.render());
    }

    println!(
        "(all three sections run on the virtual clock: rerun this example \
         and every number repeats byte-for-byte)"
    );
    Ok(())
}
