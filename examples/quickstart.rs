//! Quickstart: the full VAQF flow of paper Fig. 1 in ~30 lines.
//!
//! Input: a ViT structure (DeiT-base) + a target frame rate (24 FPS).
//! Output: the activation precision, the accelerator parameters, and the
//! generated accelerator description.
//!
//! Run with: `cargo run --release --example quickstart`

use vaqf::compiler::{compile, emit_config_json, emit_hls_cpp, CompileRequest};
use vaqf::hw::zcu102;
use vaqf::model::deit_base;

fn main() -> anyhow::Result<()> {
    // 1. The user provides the model structure and the desired frame rate.
    let request = CompileRequest {
        model: deit_base(),
        device: zcu102(),
        target_fps: 24.0,
    };

    // 2. The compilation step: feasibility (FR_max), ≤4-round binary
    //    search over activation precision, accelerator parameter
    //    optimization per §5.3.2.
    let outcome = compile(&request)?;

    println!("=== VAQF quickstart: DeiT-base @ 24 FPS on ZCU102 ===\n");
    println!("FR_max (all-binary probe): {:.1} FPS", outcome.fr_max);
    for round in &outcome.rounds {
        println!(
            "  search: {:>2}-bit activations → {:>5.1} FPS ({})",
            round.bits,
            round.fps,
            if round.feasible { "ok" } else { "too slow" }
        );
    }

    let s = &outcome.design.summary;
    println!("\nchosen: W1A{} ", outcome.act_bits);
    println!("  predicted frame rate : {:.1} FPS (target {:.0})", s.fps, request.target_fps);
    println!("  throughput           : {:.1} GOPS", s.gops);
    println!("  power                : {:.1} W  ({:.2} FPS/W)", s.power_w, s.fps_per_w);
    println!(
        "  resources            : {} DSP ({:.0}%), {:.0}k LUT ({:.0}%), {:.1} BRAM36 ({:.0}%)",
        s.utilization.dsp,
        s.utilization_pct.dsp,
        s.utilization.lut as f64 / 1e3,
        s.utilization_pct.lut,
        s.utilization.bram18k as f64 / 2.0,
        s.utilization_pct.bram18k
    );

    // 3. On the software side the chosen precision drives QAT
    //    (python/compile/train.py); on the hardware side the parameters
    //    drive the generated accelerator:
    let structure = request.model.structure(Some(outcome.act_bits));
    let cpp = emit_hls_cpp(&outcome, &structure, &request.device);
    let header: String = cpp.lines().take(18).collect::<Vec<_>>().join("\n");
    println!("\n--- generated HLS description (head) ---\n{header}\n...");

    let config = emit_config_json(&outcome, &request.device);
    println!(
        "\n--- simulator config ---\n{}",
        config.get("params").unwrap().pretty()
    );
    Ok(())
}
