//! Quickstart: the full VAQF flow of paper Fig. 1 in ~30 lines of
//! `vaqf::api`.
//!
//! Input: a ViT structure (DeiT-base) + a target frame rate (24 FPS).
//! Output: the activation precision, the accelerator parameters, and the
//! generated accelerator description — all from one typed pipeline:
//! `TargetSpec → Session → CompiledDesign`.
//!
//! Run with: `cargo run --release --example quickstart`

use vaqf::api::{Result, TargetSpec};

fn main() -> Result<()> {
    // 1. The user provides the model structure and the desired frame rate.
    let session = TargetSpec::new()
        .model_preset("deit-base")
        .device_preset("zcu102")
        .target_fps(24.0)
        .session()?;

    // 2. The compilation step: feasibility (FR_max), ≤4-round binary
    //    search over activation precision, accelerator parameter
    //    optimization per §5.3.2.
    let design = session.compile()?;
    let outcome = design.outcome().expect("compile() records the search outcome");

    println!("=== VAQF quickstart: DeiT-base @ 24 FPS on ZCU102 ===\n");
    println!("FR_max (all-binary probe): {:.1} FPS", outcome.fr_max);
    for round in &outcome.rounds {
        println!(
            "  search: {:>2}-bit activations → {:>5.1} FPS ({})",
            round.bits,
            round.fps,
            if round.feasible { "ok" } else { "too slow" }
        );
    }

    let s = design.summary();
    println!("\nchosen: W1A{} ", outcome.act_bits);
    println!(
        "  predicted frame rate : {:.1} FPS (target {:.0})",
        s.fps,
        session.target().target_fps
    );
    println!("  throughput           : {:.1} GOPS", s.gops);
    println!("  power                : {:.1} W  ({:.2} FPS/W)", s.power_w, s.fps_per_w);
    println!(
        "  resources            : {} DSP ({:.0}%), {:.0}k LUT ({:.0}%), {:.1} BRAM36 ({:.0}%)",
        s.utilization.dsp,
        s.utilization_pct.dsp,
        s.utilization.lut as f64 / 1e3,
        s.utilization_pct.lut,
        s.utilization.bram18k as f64 / 2.0,
        s.utilization_pct.bram18k
    );

    // 3. On the software side the chosen precision drives QAT
    //    (python/compile/train.py); on the hardware side the parameters
    //    drive the generated accelerator:
    let cpp = design.hls_source();
    let header: String = cpp.lines().take(18).collect::<Vec<_>>().join("\n");
    println!("\n--- generated HLS description (head) ---\n{header}\n...");

    let config = design.config_json();
    println!(
        "\n--- simulator config ---\n{}",
        config.get("params").unwrap().pretty()
    );
    Ok(())
}
