//! Co-design exploration: the motivating workload of the paper's intro —
//! "which (model, board, frame-rate) combinations are deployable, and at
//! what precision?"
//!
//! Sweeps DeiT-{tiny,small,base} across ZCU102 / ZCU111 / a small edge
//! device and a ladder of real-time targets (video: 15/24/30/60 FPS),
//! printing the feasibility frontier the way a deployment engineer would
//! read it.
//!
//! Run with: `cargo run --release --example codesign_explore`

use vaqf::compiler::{compile, CompileRequest};
use vaqf::hw::DevicePreset;
use vaqf::model::VitPreset;

fn main() {
    let targets = [15.0, 24.0, 30.0, 60.0];
    println!("=== VAQF co-design exploration ===");
    println!(
        "cell = chosen activation precision (predicted FPS) | '—' = infeasible (FR_tgt > FR_max)\n"
    );
    for device in [DevicePreset::Zcu102, DevicePreset::Zcu111, DevicePreset::GenericEdge] {
        let dev = device.device();
        println!("device {}  ({} DSP, {}k LUT)", dev.name, dev.budget.dsp, dev.budget.lut / 1000);
        print!("{:<12}", "model");
        for t in targets {
            print!(" | {t:>14.0} FPS");
        }
        println!();
        for model in VitPreset::all() {
            let cfg = model.config();
            print!("{:<12}", cfg.name);
            for &t in &targets {
                let req = CompileRequest {
                    model: cfg.clone(),
                    device: dev.clone(),
                    target_fps: t,
                };
                match compile(&req) {
                    Ok(out) => print!(
                        " | W1A{:<2} ({:>6.1}) ",
                        out.act_bits, out.design.summary.fps
                    ),
                    Err(_) => print!(" | {:^14} ", "—"),
                }
            }
            println!();
        }
        println!();
    }
    println!("reading: lower-precision cells trade accuracy (Table 2) for frame rate (Table 5).");
}
