//! Co-design exploration: the motivating workload of the paper's intro —
//! "which (model, board, frame-rate) combinations are deployable, and at
//! what precision?"
//!
//! Sweeps DeiT-{tiny,small,base} across ZCU102 / ZCU111 / a small edge
//! device and a ladder of real-time targets (video: 15/24/30/60 FPS),
//! printing the feasibility frontier the way a deployment engineer would
//! read it. Each cell is one `vaqf::api` session; infeasible targets
//! surface as the typed `VaqfError::Infeasible`.
//!
//! Run with: `cargo run --release --example codesign_explore`

use vaqf::api::TargetSpec;

fn main() {
    let targets = [15.0, 24.0, 30.0, 60.0];
    let devices = ["zcu102", "zcu111", "generic-edge"];
    let models = ["deit-tiny", "deit-small", "deit-base"];
    println!("=== VAQF co-design exploration ===");
    println!(
        "cell = chosen activation precision (predicted FPS) | '—' = infeasible (FR_tgt > FR_max)\n"
    );
    for device in devices {
        let session = TargetSpec::new()
            .device_preset(device)
            .session()
            .expect("device presets resolve");
        let dev = &session.target().device;
        println!("device {}  ({} DSP, {}k LUT)", dev.name, dev.budget.dsp, dev.budget.lut / 1000);
        print!("{:<12}", "model");
        for t in targets {
            print!(" | {t:>14.0} FPS");
        }
        println!();
        for model in models {
            // One session per (model, device): the fps ladder reuses the
            // session's cached baseline design-space search.
            let cell_session = TargetSpec::new()
                .model_preset(model)
                .device_preset(device)
                .session()
                .expect("presets resolve");
            print!("{model:<12}");
            for &t in &targets {
                match cell_session.compile_at(t) {
                    Ok(design) => print!(
                        " | W1A{:<2} ({:>6.1}) ",
                        design.act_bits().unwrap_or(16),
                        design.summary().fps
                    ),
                    // VaqfError::Infeasible (FR_tgt > FR_max) and friends.
                    Err(_) => print!(" | {:^14} ", "—"),
                }
            }
            println!();
        }
        println!();
    }
    println!("reading: lower-precision cells trade accuracy (Table 2) for frame rate (Table 5).");
}
