//! Multi-stream serving on a pool of simulated accelerators.
//!
//! Compiles one W1A8 micro-ViT design, then serves four independent
//! synthetic camera streams (each with its own bounded queue, pacing and
//! a 25 ms latency SLA) through worker pools of growing size, under every
//! dispatch policy — all on the deterministic virtual clock, so the whole
//! sweep finishes in well under a second of host time while simulating
//! seconds of traffic.
//!
//! The closing section reruns one configuration with the cycle-level
//! functional simulator on real worker threads (wall clock) to show the
//! same builder drives live serving.
//!
//! Run with: `cargo run --release --example multi_stream_serving`

use vaqf::api::{Result, ServeClock, TargetSpec};

fn main() -> Result<()> {
    println!("=== multi-stream serving: 4 cameras → W simulated accelerators ===\n");
    let session = TargetSpec::new()
        .model(vaqf::model::micro())
        .device_preset("zcu102")
        .session()?;
    let design = session.compile_for_bits(Some(8))?;
    println!(
        "compiled {}: predicted {:.0} FPS per accelerator instance\n",
        design.summary().label,
        design.summary().fps
    );

    // Offer well above one instance's capacity so scheduling matters.
    let per_stream_fps = design.summary().fps * 0.6;

    for policy in vaqf::coordinator::POLICY_NAMES {
        println!("--- policy: {policy} (virtual clock, analytic workers) ---");
        for workers in [1usize, 2, 4] {
            let report = design
                .server()
                .streams(4)
                .workers(workers)
                .policy(policy)
                .offered_fps(per_stream_fps)
                .frames(240)
                .queue_depth(4)
                .sla_ms(25.0)
                .analytic()
                .clock(ServeClock::Virtual)
                .run()?;
            let a = &report.aggregate;
            println!(
                "  {workers} worker(s): {fps:>7.1} FPS achieved  \
                 ({c} completed, {d} dropped, {v} SLA violations, p99 {p99:.2} ms)",
                fps = a.achieved_fps,
                c = a.completed,
                d = a.dropped,
                v = a.sla_violations,
                p99 = a.e2e_latency.p99 * 1e3,
            );
        }
    }

    println!("\n--- wall clock, cycle-level simulated workers ---");
    let report = design
        .server()
        .streams(4)
        .workers(2)
        .policy("weighted-sla")
        .offered_fps(120.0)
        .frames(30)
        .queue_depth(4)
        .sla_ms(50.0)
        .simulated(false)
        .run()?;
    println!("{}", report.render());

    println!(
        "(virtual-clock runs are byte-reproducible: rerun this example and \
         the per-policy numbers will not change)"
    );
    Ok(())
}
