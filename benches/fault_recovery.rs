//! Bench: fault injection, failover and graceful degradation.
//!
//! Compiles the DeiT-base preset for the ZCU102 at the paper's 24 FPS
//! target, then measures `vaqf::fault` end to end on the deterministic
//! virtual clock:
//!
//! 1. **availability vs crash rate** — a seeded Poisson fault generator
//!    sweeps mean crash rates over a 4-worker pool; availability, p99
//!    end-to-end latency and frames lost to the retry budget land per
//!    rate, with frame conservation asserted on every run;
//! 2. **degrade vs drop** — under a sustained 3× throttle, a precision
//!    ladder (W1A8 → W1A6 → W1A4 from the compiled session) is compared
//!    against plain drop-frames shedding at equal board count; the gated
//!    claim is `sla_violations_degrade ≤ sla_violations_drop`;
//! 3. **single crash + hot spare** — the 2-shard pipeline takes one board
//!    crash with a spare in inventory; the gated claim is
//!    `availability_single_crash_spare ≥ 0.99`;
//! 4. **byte reproducibility** — the scheduler and pipeline fault
//!    scenarios each run twice; `byte_identical` is 1 only when both
//!    replays render byte-identical JSON.
//!
//! Everything lands in `BENCH_faults.json`. Run with
//! `cargo bench --bench fault_recovery` (append `-- --quick` for the
//! CI-sized subset).

use vaqf::api::{
    FailoverStrategy, FaultPlan, GeneratorSpec, MultiServingReport, RecoveryConfig, Result,
    TargetSpec, VaqfError,
};
use vaqf::util::bench::{bench_output_path, JsonReport};
use vaqf::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let quick = args.has_flag("quick");
    let frames: u64 = if quick { 300 } else { 1200 };
    let mut report = JsonReport::new("fault_recovery", if quick { "quick" } else { "full" });

    println!("=== fault recovery: DeiT-base on zcu102 ===\n");
    let session = TargetSpec::new()
        .model_preset("deit-base")
        .device_preset("zcu102")
        .target_fps(24.0)
        .session()?;
    let design = session.compile()?;
    let base = design.frame_latency_s();
    println!(
        "compiled {}: {:.1} FPS per worker predicted\n",
        design.summary().label,
        design.summary().fps
    );

    // -- 1. availability & tail latency vs crash rate -----------------------
    //
    // 4 streams × 20 FPS against 4 workers (≈ 70% utilisation when
    // healthy). Crashes repair after ~200 ms, so higher rates directly
    // translate into lower availability and fatter tails.
    println!("--- availability vs crash rate (4 workers, repair ≈ 200 ms) ---");
    let offered_fps = 20.0;
    let horizon_s = frames as f64 / offered_fps;
    let crash_scenario = |crash_rate_hz: f64| -> Result<MultiServingReport> {
        let plan = FaultPlan::new()
            .generator(GeneratorSpec {
                seed: 11,
                units: 4,
                horizon_s,
                crash_rate_hz,
                mttr_s: 0.2,
                slow_rate_hz: 0.0,
                slow_factor: 1.0,
                corrupt_rate_hz: 0.0,
            })
            .recovery(RecoveryConfig {
                max_retries: 3,
                ..Default::default()
            });
        design
            .server()
            .streams(4)
            .workers(4)
            .policy("least-loaded")
            .offered_fps(offered_fps)
            .frames(frames)
            .queue_depth(4)
            .sla_ms(base * 3.0 * 1e3)
            .analytic()
            .virtual_clock()
            .faults(plan)
            .run()
    };
    for crash_rate_hz in [0.0, 0.5, 2.0, 8.0] {
        let r = crash_scenario(crash_rate_hz)?;
        let a = &r.aggregate;
        if a.offered != a.completed + a.dropped + a.failed {
            return Err(VaqfError::runtime(anyhow::anyhow!(
                "conservation broke at rate {crash_rate_hz}: {} != {} + {} + {}",
                a.offered,
                a.completed,
                a.dropped,
                a.failed
            )));
        }
        let f = r.faults.as_ref().expect("fault block present");
        let tag = format!("crash_rate={crash_rate_hz}");
        report.metric(&format!("{tag} availability"), f.availability, "frac");
        report.metric(&format!("{tag} p99_e2e"), a.e2e_latency.p99 * 1e3, "ms");
        report.metric(&format!("{tag} failed"), a.failed as f64, "frames");
        report.metric(&format!("{tag} retries"), f.retries as f64, "frames");
        report.metric(&format!("{tag} mttr"), f.mttr_s * 1e3, "ms");
    }
    println!();

    // -- 2. graceful degradation vs drop-frames ------------------------------
    //
    // A sustained 3× throttle on every worker pushes the pool past
    // saturation. Same boards, same traffic: the only difference is
    // whether the scheduler sheds precision (ladder) or frames (drops).
    println!("--- degrade ladder vs drop-frames under a 3x throttle ---");
    let ladder = session.precision_ladder(&[8, 6, 4])?;
    let throttled = |with_ladder: bool| -> Result<MultiServingReport> {
        let mut plan = FaultPlan::new();
        for unit in 0..2 {
            plan = plan.slow_down_at(0.05, unit, 3.0);
        }
        let mut b = design
            .server()
            .streams(2)
            .workers(2)
            .policy("weighted-sla")
            .offered_fps(design.summary().fps * 0.8)
            .frames(frames / 2)
            .queue_depth(2)
            .sla_ms(base * 2.5 * 1e3)
            .analytic()
            .virtual_clock()
            .faults(plan);
        if with_ladder {
            b = b.degrade_ladder(ladder.clone());
        }
        b.run()
    };
    let degrade = throttled(true)?;
    let drop = throttled(false)?;
    let switches = degrade
        .faults
        .as_ref()
        .map(|f| f.precision_switches.len())
        .unwrap_or(0);
    report.metric(
        "sla_violations_degrade",
        degrade.aggregate.sla_violations as f64,
        "frames",
    );
    report.metric(
        "sla_violations_drop",
        drop.aggregate.sla_violations as f64,
        "frames",
    );
    report.metric(
        "completed_degrade",
        degrade.aggregate.completed as f64,
        "frames",
    );
    report.metric("completed_drop", drop.aggregate.completed as f64, "frames");
    report.metric("precision_switches", switches as f64, "count");
    println!();

    // -- 3. pipeline: single crash with a hot spare --------------------------
    println!("--- 2-shard pipeline: one crash, one hot spare ---");
    let sharded = design.shards(2).map_err(VaqfError::runtime)?;
    let pipe_frames = if quick { 600 } else { 2000 };
    let pipe_plan = || {
        FaultPlan::new()
            .crash_at(50.0 * base, 0)
            .recovery(RecoveryConfig {
                spares: 1,
                swap_s: base,
                ..Default::default()
            })
    };
    let pipe = sharded
        .report_with_faults(pipe_frames, &pipe_plan(), FailoverStrategy::Spare)
        .map_err(VaqfError::runtime)?;
    let pf = pipe.pipeline.faults.as_ref().expect("fault block present");
    report.metric(
        "availability_single_crash_spare",
        pf.availability,
        "frac",
    );
    report.metric("hot_swaps", pf.hot_swaps as f64, "count");
    report.metric("rerun_frames", pf.rerun_frames as f64, "frames");
    report.metric(
        "steady_fps_under_crash",
        pipe.pipeline.steady_fps,
        "fps",
    );
    println!();

    // -- 4. byte reproducibility ---------------------------------------------
    println!("--- byte reproducibility (two executions each) ---");
    let sched_a = crash_scenario(2.0)?.to_json().pretty();
    let sched_b = crash_scenario(2.0)?.to_json().pretty();
    let pipe_b = sharded
        .report_with_faults(pipe_frames, &pipe_plan(), FailoverStrategy::Spare)
        .map_err(VaqfError::runtime)?
        .to_json()
        .pretty();
    let identical = sched_a == sched_b && pipe.to_json().pretty() == pipe_b;
    report.metric("byte_identical", if identical { 1.0 } else { 0.0 }, "bool");

    report
        .write(bench_output_path("BENCH_faults.json"))
        .map_err(VaqfError::runtime)?;
    Ok(())
}
