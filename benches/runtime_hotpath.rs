//! Bench: the simulator + serving hot paths.
//!
//! Section 1 (always runs, no artifacts needed): the compute-engine
//! kernels on DeiT-base-shaped layers — scalar reference vs the bit-packed
//! XNOR/popcount backend, per activation precision, plus row-parallel
//! scaling. Speedups land in `BENCH_hotpath.json` so the perf trajectory
//! is tracked across PRs (methodology: EXPERIMENTS.md §Perf).
//!
//! Section 2: prepared-model execution — the whole-model simulator loop
//! with the per-model plan (packed weights, pre-quantized fixed16,
//! per-layer timing) built once and a reused workspace, against a
//! transliteration of the pre-plan path that re-lays the weights out and
//! allocates on every call. Reports per-frame latency (single and
//! batched) and per-frame heap-allocation counts measured with a counting
//! global allocator.
//!
//! Section 3 (requires `make artifacts`): PJRT inference latency per
//! artifact variant, frame-source + queue overhead, and end-to-end serving
//! throughput. Skips gracefully without artifacts.
//!
//! Run with: `cargo bench --bench runtime_hotpath` (append `-- --quick`
//! for the CI-sized subset).

use std::alloc::{GlobalAlloc, Layout, System};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

use vaqf::coordinator::{serve, FrameSource, ServeConfig};
use vaqf::hw::zcu102;
use vaqf::model::deit_base;
use vaqf::perf::AcceleratorParams;
use vaqf::quant::{binarize, pack_bit_planes, pack_sign_planes};
use vaqf::runtime::{InferenceEngine, Manifest, PjrtBackend};
use vaqf::sim::{generate_weights, reference_forward, Backend, ComputeEngine, ModelExecutor};
use vaqf::util::bench::{bench_output_path, report_metric, Bench, JsonReport};
use vaqf::util::parallel::default_threads;
use vaqf::util::rng::SplitMix64;
use vaqf::util::simd::{self, SimdTier};

/// Counting allocator: the per-frame allocation numbers in
/// `BENCH_hotpath.json` are exact counts of `alloc`/`realloc`/
/// `alloc_zeroed` calls (methodology: EXPERIMENTS.md §Perf).
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_calls() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

/// DeiT-base geometry: 196 patches + CLS, embed 768, heads of 64.
const F: usize = 197;
const HEAD: usize = 64;

/// The four binary-weight FC shapes of a DeiT-base encoder layer.
const FC_SHAPES: [(&str, usize, usize); 4] = [
    ("qkv", 768, 2304),
    ("proj", 768, 768),
    ("mlp1", 768, 3072),
    ("mlp2", 3072, 768),
];

fn accel_params(bits: u8) -> AcceleratorParams {
    let g_q = AcceleratorParams::g_q_for(64, bits);
    AcceleratorParams {
        t_m: 16,
        t_n: 4,
        t_m_q: 160,
        t_n_q: g_q,
        g: 4,
        g_q,
        p_h: 4,
        act_bits: Some(bits),
    }
}

fn engine(bits: u8, backend: Backend, threads: usize) -> ComputeEngine {
    ComputeEngine::new(accel_params(bits), zcu102())
        .with_backend(backend)
        .with_threads(threads)
}

fn randn(rng: &mut SplitMix64, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.next_f32_range(-1.5, 1.5)).collect()
}

/// Section 1: kernel-level scalar vs packed on DeiT-base shapes.
fn engine_section(quick: bool, report: &mut JsonReport) {
    let mut bench = Bench::heavy();
    if quick {
        bench.warmup_iters = 1;
        bench.min_iters = 2;
        bench.max_iters = 8;
        bench.budget = std::time::Duration::from_millis(600);
    }
    let mut rng = SplitMix64::new(20260729);

    println!("== compute engine: scalar vs bit-packed (DeiT-base shapes, 1 thread) ==");
    let fc_shapes: &[(&str, usize, usize)] = if quick { &FC_SHAPES[..1] } else { &FC_SHAPES };
    let bit_widths: &[u8] = if quick { &[8] } else { &[8, 6, 4, 1] };

    // fc_binary: every shape at W1A8, plus the precision sweep on qkv.
    for &(name, n, m) in fc_shapes {
        let x = randn(&mut rng, F * n);
        let wb = binarize(&randn(&mut rng, n * m), n, m);
        for &bits in bit_widths {
            if bits != 8 && name != "qkv" {
                continue; // precision sweep only on the largest shape
            }
            let tag = format!("fc_binary {name} {n}x{m} w1a{bits}");
            let scalar = engine(bits, Backend::Scalar, 1);
            let packed = engine(bits, Backend::Packed, 1);
            let rs = bench.run(&format!("{tag} scalar"), || {
                let _ = scalar.fc_binary(&x, &wb, F);
            });
            report.result(&rs);
            let rp = bench.run(&format!("{tag} packed"), || {
                let _ = packed.fc_binary(&x, &wb, F);
            });
            report.result(&rp);
            report.metric(
                &format!("{tag} speedup (packed/scalar)"),
                rs.mean_s() / rp.mean_s(),
                "x",
            );
        }
    }

    // qq_matmul (attention): the Packed backend runs plane-pair popcounts
    // below the bits² crossover and the compact i32 loop above it (see
    // sim::kernels::qq_packed_profitable for the tuned rationale) — sweep
    // both sides so the crossover stays anchored to measured numbers.
    if !quick {
        println!("\n== attention qq_matmul: scalar vs packed ==");
        for &(name, k, m) in &[("qk", HEAD, F), ("sv", F, HEAD)] {
            let a = randn(&mut rng, F * k);
            let b = randn(&mut rng, k * m);
            for &bits in &[8u8, 6, 4, 1] {
                let tag = format!("qq_{name} {k}x{m} a{bits}");
                let scalar = engine(bits, Backend::Scalar, 1);
                let packed = engine(bits, Backend::Packed, 1);
                let rs = bench.run(&format!("{tag} scalar"), || {
                    let _ = scalar.qq_matmul(&a, &b, F, k, m);
                });
                report.result(&rs);
                let rp = bench.run(&format!("{tag} packed"), || {
                    let _ = packed.qq_matmul(&a, &b, F, k, m);
                });
                report.result(&rp);
                report.metric(
                    &format!("{tag} speedup (packed/scalar)"),
                    rs.mean_s() / rp.mean_s(),
                    "x",
                );
            }
        }
    }

    // Row-parallel scaling: packed backend, 1 thread vs the environment
    // default, on the largest FC and the fixed16 DSP path.
    let threads = default_threads();
    println!("\n== row-parallel scaling (1 → {threads} threads) ==");
    {
        let (name, n, m) = FC_SHAPES[0];
        let x = randn(&mut rng, F * n);
        let wb = binarize(&randn(&mut rng, n * m), n, m);
        let e1 = engine(8, Backend::Packed, 1);
        let en = engine(8, Backend::Packed, threads);
        let es = engine(8, Backend::Scalar, 1);
        let r1 = bench.run(&format!("fc_binary {name} packed 1 thread"), || {
            let _ = e1.fc_binary(&x, &wb, F);
        });
        report.result(&r1);
        let rn = bench.run(&format!("fc_binary {name} packed {threads} threads"), || {
            let _ = en.fc_binary(&x, &wb, F);
        });
        report.result(&rn);
        report.metric(
            &format!("fc_binary {name} thread scaling"),
            r1.mean_s() / rn.mean_s(),
            "x",
        );
        // Headline: the full hot-path win over the seed implementation
        // (scalar kernels, single thread — what the simulator ran before
        // this backend existed).
        let rs = bench.run(&format!("fc_binary {name} scalar 1 thread"), || {
            let _ = es.fc_binary(&x, &wb, F);
        });
        report.result(&rs);
        report.metric(
            &format!("fc_binary {name} w1a8 hot-path speedup (packed×{threads}t / seed)"),
            rs.mean_s() / rn.mean_s(),
            "x",
        );

        let w = randn(&mut rng, n * m);
        let r1 = bench.run(&format!("fc_fixed16 {name} 1 thread"), || {
            let _ = e1.fc_fixed16(&x, &w, F, n, m);
        });
        report.result(&r1);
        let rn = bench.run(&format!("fc_fixed16 {name} {threads} threads"), || {
            let _ = en.fc_fixed16(&x, &w, F, n, m);
        });
        report.result(&rn);
        report.metric(
            &format!("fc_fixed16 {name} thread scaling"),
            r1.mean_s() / rn.mean_s(),
            "x",
        );
    }
}

/// Section 1b: the SIMD popcount primitive itself, per dispatch tier, on
/// a DeiT-base qkv-shaped panel (768×2304 W1A8 — 8 activation planes ×
/// 2304 packed weight columns per "frame" of dots). Per-tier results are
/// cross-checked bit-for-bit before timing; the speedup ratio lands in
/// `BENCH_hotpath.json` and CI gates it at ≥ 0.9 — a vector tier must
/// never lose to the scalar loop it replaced (methodology:
/// EXPERIMENTS.md §Perf).
fn simd_section(quick: bool, report: &mut JsonReport) {
    let mut bench = Bench::heavy();
    if quick {
        bench.warmup_iters = 1;
        bench.min_iters = 2;
        bench.max_iters = 8;
        bench.budget = std::time::Duration::from_millis(400);
    }
    let (n, m, bits) = (768usize, 2304usize, 8u32);
    let mut rng = SplitMix64::new(20260808);
    let vals: Vec<i32> = (0..n)
        .map(|_| {
            let hi = (1i64 << (bits - 1)) - 1;
            let lo = -(1i64 << (bits - 1));
            (lo + rng.next_below((hi - lo + 1) as u64) as i64) as i32
        })
        .collect();
    let row = pack_bit_planes(&vals, bits);
    let signs: Vec<bool> = (0..n * m).map(|_| rng.next_below(2) == 1).collect();
    let w = pack_sign_planes(&signs, n, m);

    let dot_all = |tier: SimdTier| -> u64 {
        let mut pop = 0u64;
        for j in 0..m {
            let col = w.col(j);
            for b in 0..bits {
                pop += simd::and_popcount_with(tier, row.plane(b), col);
            }
        }
        pop
    };

    let tiers = SimdTier::supported_tiers();
    println!(
        "\n== SIMD popcount tiers (qkv panel {n}x{m} w1a{bits}, active tier: {}) ==",
        simd::active()
    );
    let want = dot_all(SimdTier::Scalar);
    for &tier in &tiers {
        assert_eq!(dot_all(tier), want, "tier {tier} diverged from the scalar tier");
    }
    let mut scalar_s = f64::NAN;
    for &tier in &tiers {
        let r = bench.run(&format!("and_popcount qkv panel, {tier} tier"), || {
            let _ = std::hint::black_box(dot_all(tier));
        });
        report.result(&r);
        if tier == SimdTier::Scalar {
            scalar_s = r.mean_s();
        } else {
            report.metric(
                &format!("simd speedup ({tier}/scalar tier)"),
                scalar_s / r.mean_s(),
                "x",
            );
        }
    }
    report.metric("simd active tier", simd::active() as u8 as f64, "tier");
}

/// Section 2: prepared plan + workspace vs the PR 3 path, whole model.
///
/// Always DeiT-base at W1A8 (the acceptance trajectory tracks exactly
/// that point); `--quick` only trims iteration counts. The weights live
/// in the executor (`exec.weights()`) so the ~100M-parameter model exists
/// once.
fn prepared_section(quick: bool, report: &mut JsonReport) {
    let mut bench = Bench::heavy();
    if quick {
        // Two samples minimum even in quick mode: the CI regression guard
        // gates on these metrics, and a single sample on a shared runner
        // is too noisy to gate on.
        bench.warmup_iters = 0;
        bench.min_iters = 2;
        bench.max_iters = 3;
        bench.budget = std::time::Duration::from_millis(500);
    }
    let label = "deit-base";
    let bits = 8u8;
    let threads = default_threads();
    let params = accel_params(bits);

    println!("\n== prepared-model execution ({label} W1A{bits}, packed, {threads} threads) ==");
    let weights = generate_weights(&deit_base(), 11);
    let patches = weights.synthetic_patches(0);
    let mut exec = ModelExecutor::new(weights, Some(bits), params, zcu102()).with_threads(1);
    let legacy1 = engine(bits, Backend::Packed, 1);

    // Bit-exactness cross-check before timing anything (also warms the
    // prepared workspace for the allocation count below).
    let legacy_logits = reference_forward(&legacy1, exec.weights(), &patches);
    let (prep_logits, _) = exec.run_frame(&patches);
    assert_eq!(
        legacy_logits, prep_logits,
        "prepared path diverged from the PR3-style path"
    );
    println!("  cross-check: prepared logits == PR3-path logits (bit-exact)");

    // Steady-state heap-allocation accounting, measured at 1 thread so
    // the counts are the loop's own allocations (thread spawns excluded;
    // see EXPERIMENTS.md §Perf for the protocol).
    let before = alloc_calls();
    let _ = exec.run_frame(&patches);
    let prep_allocs = alloc_calls() - before;
    let before = alloc_calls();
    let _ = reference_forward(&legacy1, exec.weights(), &patches);
    let legacy_allocs = alloc_calls() - before;

    // Timing at the environment's thread fan-out.
    let mut exec = exec.with_threads(threads);
    let legacy_engine = engine(bits, Backend::Packed, threads);
    let r_legacy = bench.run(&format!("{label} w1a{bits} frame, PR3 path"), || {
        let _ = reference_forward(&legacy_engine, exec.weights(), &patches);
    });
    report.result(&r_legacy);
    let r_prep = bench.run(&format!("{label} w1a{bits} frame, prepared"), || {
        let _ = exec.run_frame(&patches);
    });
    report.result(&r_prep);

    let batch_n: usize = if quick { 4 } else { 8 };
    let frames: Vec<Vec<f32>> = (0..batch_n as u64)
        .map(|i| exec.weights().synthetic_patches(i))
        .collect();
    let r_batch = bench.run(&format!("{label} w1a{bits} run_batch({batch_n})"), || {
        let _ = exec.run_batch(&frames);
    });
    report.result(&r_batch);
    let batched_frame_s = r_batch.mean_s() / batch_n as f64;

    report.metric(
        &format!("{label} w1a{bits} per-frame latency (PR3 path)"),
        r_legacy.mean_s() * 1e3,
        "ms",
    );
    report.metric(
        &format!("{label} w1a{bits} per-frame latency (prepared)"),
        r_prep.mean_s() * 1e3,
        "ms",
    );
    report.metric(
        &format!("{label} w1a{bits} per-frame latency (batched)"),
        batched_frame_s * 1e3,
        "ms",
    );
    report.metric(
        &format!("{label} w1a{bits} per-frame speedup (prepared/PR3)"),
        r_legacy.mean_s() / r_prep.mean_s(),
        "x",
    );
    report.metric(
        &format!("{label} w1a{bits} per-frame speedup (batched/PR3)"),
        r_legacy.mean_s() / batched_frame_s,
        "x",
    );
    report.metric(
        &format!("{label} w1a{bits} heap allocs per frame (PR3 path)"),
        legacy_allocs as f64,
        "allocs",
    );
    report.metric(
        &format!("{label} w1a{bits} heap allocs per frame (prepared steady state)"),
        prep_allocs as f64,
        "allocs",
    );
    report.metric(
        &format!("{label} w1a{bits} alloc reduction (PR3/prepared)"),
        legacy_allocs as f64 / prep_allocs.max(1) as f64,
        "x",
    );
}

/// Section 3: PJRT + serving (needs artifacts; skips otherwise).
fn pjrt_section(report: &mut JsonReport) -> anyhow::Result<()> {
    let artifacts = "artifacts";
    let man = match Manifest::load(artifacts) {
        Ok(m) => m,
        Err(e) => {
            println!("\nskipping PJRT section: {e}");
            return Ok(());
        }
    };
    let mut engine = match InferenceEngine::new() {
        Ok(e) => e,
        Err(e) => {
            println!("\nskipping PJRT section: {e}");
            return Ok(());
        }
    };
    for v in &man.variants {
        engine.load_variant(v)?;
    }
    let engine = Rc::new(engine);

    println!("\n== PJRT inference latency per variant ==");
    let mut bench = Bench::new();
    for v in &man.variants {
        let source = FrameSource::new(v.config.clone(), man.seed, None);
        let frame = source.make_frame(0);
        let tag = v.tag.clone();
        let e = Rc::clone(&engine);
        let r = bench.run(&format!("pjrt infer {tag}"), || {
            let _ = e.infer(&tag, &frame.patches).unwrap();
        });
        report.result(&r);
        report_metric(
            &format!("{tag} throughput"),
            1.0 / r.mean_s(),
            "frames/s",
        );
    }

    println!("\n== frame source + queue overhead (no inference) ==");
    let v0 = &man.variants[0];
    let source = FrameSource::new(v0.config.clone(), man.seed, None);
    bench.run("frame generation", || {
        let _ = source.make_frame(1);
    });

    println!("\n== end-to-end serving (pjrt backend, micro_w1a8) ==");
    if man.find("micro_w1a8").is_some() {
        let cfg = ServeConfig {
            offered_fps: 500.0,
            frames: 200,
            queue_depth: 8,
            source_seed: man.seed,
        };
        let src = FrameSource::new(
            man.find("micro_w1a8").unwrap().config.clone(),
            man.seed,
            Some(cfg.offered_fps),
        );
        let rep = serve(
            src,
            Box::new(PjrtBackend {
                engine: Rc::clone(&engine),
                tag: "micro_w1a8".into(),
            }),
            &cfg,
        )?;
        println!("{}", rep.render());
        // Coordinator overhead: e2e latency minus device latency.
        let oh = (rep.e2e_latency.mean - rep.device_latency.mean).max(0.0);
        report.metric("coordinator overhead (mean)", oh * 1e3, "ms");
        report.metric(
            "coordinator overhead fraction",
            100.0 * oh / rep.e2e_latency.mean.max(1e-12),
            "%",
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut report = JsonReport::new("runtime_hotpath", if quick { "quick" } else { "full" });

    let out = bench_output_path("BENCH_hotpath.json");
    engine_section(quick, &mut report);
    simd_section(quick, &mut report);
    report.write(&out)?;

    prepared_section(quick, &mut report);
    // Persist the sim-side numbers even if the PJRT section bails later.
    report.write(&out)?;

    pjrt_section(&mut report)?;
    report.write(&out)?;
    Ok(())
}
