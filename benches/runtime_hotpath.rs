//! Bench: the L3 hot path — PJRT inference latency per artifact variant,
//! frame-source + queue overhead, and end-to-end serving throughput.
//!
//! Requires `make artifacts`. Run with: `cargo bench --bench runtime_hotpath`

use std::rc::Rc;

use vaqf::coordinator::{serve, FrameSource, ServeConfig};
use vaqf::runtime::{InferenceEngine, Manifest, PjrtBackend};
use vaqf::util::bench::{report_metric, Bench};

fn main() -> anyhow::Result<()> {
    let artifacts = "artifacts";
    let man = match Manifest::load(artifacts) {
        Ok(m) => m,
        Err(e) => {
            println!("skipping runtime_hotpath: {e}");
            return Ok(());
        }
    };
    let mut engine = InferenceEngine::new()?;
    for v in &man.variants {
        engine.load_variant(v)?;
    }
    let engine = Rc::new(engine);

    println!("== PJRT inference latency per variant ==");
    let mut bench = Bench::new();
    for v in &man.variants {
        let source = FrameSource::new(v.config.clone(), man.seed, None);
        let frame = source.make_frame(0);
        let tag = v.tag.clone();
        let e = Rc::clone(&engine);
        let r = bench.run(&format!("pjrt infer {tag}"), || {
            let _ = e.infer(&tag, &frame.patches).unwrap();
        });
        report_metric(
            &format!("{tag} throughput"),
            1.0 / r.mean_s(),
            "frames/s",
        );
    }

    println!("\n== frame source + queue overhead (no inference) ==");
    let v0 = &man.variants[0];
    let source = FrameSource::new(v0.config.clone(), man.seed, None);
    bench.run("frame generation", || {
        let _ = source.make_frame(1);
    });

    println!("\n== end-to-end serving (pjrt backend, micro_w1a8) ==");
    if man.find("micro_w1a8").is_some() {
        let cfg = ServeConfig {
            offered_fps: 500.0,
            frames: 200,
            queue_depth: 8,
            source_seed: man.seed,
        };
        let src = FrameSource::new(
            man.find("micro_w1a8").unwrap().config.clone(),
            man.seed,
            Some(cfg.offered_fps),
        );
        let report = serve(
            src,
            Box::new(PjrtBackend {
                engine: Rc::clone(&engine),
                tag: "micro_w1a8".into(),
            }),
            &cfg,
        )?;
        println!("{}", report.render());
        // Coordinator overhead: e2e latency minus device latency.
        let oh = (report.e2e_latency.mean - report.device_latency.mean).max(0.0);
        report_metric("coordinator overhead (mean)", oh * 1e3, "ms");
        report_metric(
            "coordinator overhead fraction",
            100.0 * oh / report.e2e_latency.mean.max(1e-12),
            "%",
        );
    }
    Ok(())
}
