//! Bench: design-choice ablations (DESIGN.md §3) — quantify each §5
//! optimization technique by disabling it in the latency model and
//! re-measuring the three Table-5 designs.
//!
//! Run with: `cargo bench --bench ablations`

use vaqf::compiler::{optimize_baseline, optimize_for_bits};
use vaqf::hw::zcu102;
use vaqf::model::deit_base;
use vaqf::perf::{model_cycles_opt, AcceleratorParams, ModelOptions};
use vaqf::util::bench::report_metric;

fn main() {
    let dev = zcu102();
    let model = deit_base();
    let base = optimize_baseline(&model.structure(None), &dev);

    let designs: Vec<(String, Option<u8>, AcceleratorParams)> = [None, Some(8), Some(6)]
        .into_iter()
        .map(|bits| {
            let label = bits.map(|b| format!("W1A{b}")).unwrap_or("W32A32".into());
            let params = match bits {
                None => base,
                Some(b) => {
                    optimize_for_bits(&model.structure(Some(b)), &base, &dev, b)
                        .unwrap()
                        .params
                }
            };
            (label, bits, params)
        })
        .collect();

    let ablations: [(&str, ModelOptions); 5] = [
        ("full design (paper)", ModelOptions::default()),
        (
            "w/o data packing (§5.3.1)",
            ModelOptions {
                data_packing: false,
                ..Default::default()
            },
        ),
        (
            "w/o double buffering (Eq. 9)",
            ModelOptions {
                double_buffering: false,
                ..Default::default()
            },
        ),
        (
            "w/o binary-weight packing",
            ModelOptions {
                binary_weight_packing: false,
                ..Default::default()
            },
        ),
        (
            "w/o host-op overlap",
            ModelOptions {
                host_overlap: false,
                ..Default::default()
            },
        ),
    ];

    println!("== design-choice ablations: predicted FPS per design ==\n");
    print!("{:<32}", "configuration");
    for (label, _, _) in &designs {
        print!(" | {label:>8}");
    }
    println!();
    println!("{}", "-".repeat(32 + designs.len() * 11));

    let mut full_fps = Vec::new();
    for (name, opts) in &ablations {
        print!("{name:<32}");
        for (i, (_, bits, params)) in designs.iter().enumerate() {
            let s = model.structure(*bits);
            let (cycles, _) = model_cycles_opt(&s, params, &dev, opts);
            let fps = dev.fps(cycles);
            if name.starts_with("full") {
                full_fps.push(fps);
            }
            let suffix = if name.starts_with("full") {
                "".to_string()
            } else {
                format!(" ({:>4.2}x)", fps / full_fps[i])
            };
            print!(" | {fps:>5.1}{suffix:>8}");
        }
        println!();
    }

    println!("\nreading: each row disables one technique; the parenthesised factor");
    println!("is the FPS retained relative to the full design. Data packing and");
    println!("double buffering are the load-bearing §5 techniques, exactly as the");
    println!("paper argues.");

    // Contribution summary for EXPERIMENTS.md.
    println!();
    for (i, (label, bits, params)) in designs.iter().enumerate() {
        let s = model.structure(*bits);
        let no_pack = model_cycles_opt(
            &s,
            params,
            &dev,
            &ModelOptions {
                data_packing: false,
                ..Default::default()
            },
        )
        .0;
        let full = model_cycles_opt(&s, params, &dev, &ModelOptions::default()).0;
        report_metric(
            &format!("{label}: packing speedup contribution"),
            no_pack as f64 / full as f64,
            "x",
        );
        let _ = i;
    }
}
