//! Bench: design-choice ablations (DESIGN.md §3) — quantify each §5
//! optimization technique by disabling it in the latency model and
//! re-measuring the three Table-5 designs, plus the *measured* software
//! analog: scalar vs bit-packed simulator kernels per quantized design
//! (the same §5.3.1 packing idea, observed as host wall-clock instead of
//! modeled cycles). Results land in `BENCH_ablations.json`.
//!
//! Run with: `cargo bench --bench ablations`

use vaqf::compiler::{optimize_baseline, optimize_for_bits};
use vaqf::hw::zcu102;
use vaqf::model::deit_base;
use vaqf::perf::{model_cycles_opt, AcceleratorParams, ModelOptions};
use vaqf::quant::binarize;
use vaqf::sim::{Backend, ComputeEngine};
use vaqf::util::bench::{bench_output_path, Bench, JsonReport};
use vaqf::util::rng::SplitMix64;

fn main() {
    let dev = zcu102();
    let model = deit_base();
    let base = optimize_baseline(&model.structure(None), &dev);

    let designs: Vec<(String, Option<u8>, AcceleratorParams)> = [None, Some(8), Some(6)]
        .into_iter()
        .map(|bits| {
            let label = bits.map(|b| format!("W1A{b}")).unwrap_or("W32A32".into());
            let params = match bits {
                None => base,
                Some(b) => {
                    optimize_for_bits(&model.structure(Some(b)), &base, &dev, b)
                        .unwrap()
                        .params
                }
            };
            (label, bits, params)
        })
        .collect();

    let ablations: [(&str, ModelOptions); 5] = [
        ("full design (paper)", ModelOptions::default()),
        (
            "w/o data packing (§5.3.1)",
            ModelOptions {
                data_packing: false,
                ..Default::default()
            },
        ),
        (
            "w/o double buffering (Eq. 9)",
            ModelOptions {
                double_buffering: false,
                ..Default::default()
            },
        ),
        (
            "w/o binary-weight packing",
            ModelOptions {
                binary_weight_packing: false,
                ..Default::default()
            },
        ),
        (
            "w/o host-op overlap",
            ModelOptions {
                host_overlap: false,
                ..Default::default()
            },
        ),
    ];

    println!("== design-choice ablations: predicted FPS per design ==\n");
    print!("{:<32}", "configuration");
    for (label, _, _) in &designs {
        print!(" | {label:>8}");
    }
    println!();
    println!("{}", "-".repeat(32 + designs.len() * 11));

    let mut full_fps = Vec::new();
    for (name, opts) in &ablations {
        print!("{name:<32}");
        for (i, (_, bits, params)) in designs.iter().enumerate() {
            let s = model.structure(*bits);
            let (cycles, _) = model_cycles_opt(&s, params, &dev, opts);
            let fps = dev.fps(cycles);
            if name.starts_with("full") {
                full_fps.push(fps);
            }
            let suffix = if name.starts_with("full") {
                "".to_string()
            } else {
                format!(" ({:>4.2}x)", fps / full_fps[i])
            };
            print!(" | {fps:>5.1}{suffix:>8}");
        }
        println!();
    }

    println!("\nreading: each row disables one technique; the parenthesised factor");
    println!("is the FPS retained relative to the full design. Data packing and");
    println!("double buffering are the load-bearing §5 techniques, exactly as the");
    println!("paper argues.");

    // Contribution summary for EXPERIMENTS.md.
    let mut report = JsonReport::new("ablations", "full");
    println!();
    for (i, (label, bits, params)) in designs.iter().enumerate() {
        let s = model.structure(*bits);
        let no_pack = model_cycles_opt(
            &s,
            params,
            &dev,
            &ModelOptions {
                data_packing: false,
                ..Default::default()
            },
        )
        .0;
        let full = model_cycles_opt(&s, params, &dev, &ModelOptions::default()).0;
        report.metric(
            &format!("{label}: packing speedup contribution"),
            no_pack as f64 / full as f64,
            "x",
        );
        let _ = i;
    }

    // Measured analog on the simulator itself: the same bit-packing idea,
    // observed as host wall-clock. One DeiT-base qkv layer (197×768 @
    // 768×2304) per quantized design, scalar kernels vs packed.
    println!("\n== measured simulator kernels: scalar vs packed per design ==\n");
    let mut bench = Bench::heavy();
    let mut rng = SplitMix64::new(42);
    let (f, n, m) = (197usize, 768usize, 2304usize);
    let x: Vec<f32> = (0..f * n).map(|_| rng.next_f32_range(-1.5, 1.5)).collect();
    let w: Vec<f32> = (0..n * m).map(|_| rng.next_f32_range(-0.2, 0.2)).collect();
    let wb = binarize(&w, n, m);
    for (label, bits, params) in &designs {
        if bits.is_none() {
            continue; // W32A32 has no binary-weight datapath
        }
        let scalar = ComputeEngine::new(*params, dev.clone())
            .with_backend(Backend::Scalar)
            .with_threads(1);
        let packed = ComputeEngine::new(*params, dev.clone())
            .with_backend(Backend::Packed)
            .with_threads(1);
        let rs = bench.run(&format!("{label} fc_binary qkv scalar"), || {
            let _ = scalar.fc_binary(&x, &wb, f);
        });
        report.result(&rs);
        let rp = bench.run(&format!("{label} fc_binary qkv packed"), || {
            let _ = packed.fc_binary(&x, &wb, f);
        });
        report.result(&rp);
        report.metric(
            &format!("{label}: measured packed kernel speedup"),
            rs.mean_s() / rp.mean_s(),
            "x",
        );
    }
    if let Err(e) = report.write(bench_output_path("BENCH_ablations.json")) {
        eprintln!("could not write BENCH_ablations.json: {e}");
    }
}
