//! Bench: regenerate paper Table 6 — FPS / power / energy efficiency of
//! our accelerators vs CPU / GPU / the BERT FPGA accelerator (quoted
//! rows; DESIGN.md §Substitutions).
//!
//! Run with: `cargo bench --bench table6_efficiency`

use vaqf::compiler::{render_table6, table5_rows, table6_rows};
use vaqf::hw::zcu102;
use vaqf::model::deit_base;
use vaqf::util::bench::report_metric;

fn main() {
    let dev = zcu102();
    let rows5 = table5_rows(&deit_base(), &dev, &[8, 6]);
    let rows6 = table6_rows(&rows5);

    println!("== Table 6 regeneration ==\n");
    println!("{}", render_table6(&rows6));

    // Paper claims: W1A6 has the best FPS/W of all implementations
    // (4.05), 27.0× the CPU and 5.7× the GPU.
    let ours_w1a6 = rows6
        .iter()
        .find(|r| r.implementation.contains("W1A6"))
        .expect("w1a6 row");
    let cpu = &rows6[0];
    let gpu = &rows6[1];
    println!("paper-vs-measured energy-efficiency ratios:");
    report_metric(
        "W1A6 FPS/W vs CPU (paper 27.0x)",
        ours_w1a6.fps_per_w / cpu.fps_per_w,
        "x",
    );
    report_metric(
        "W1A6 FPS/W vs GPU (paper 5.7x)",
        ours_w1a6.fps_per_w / gpu.fps_per_w,
        "x",
    );
    let best = rows6
        .iter()
        .max_by(|a, b| a.fps_per_w.total_cmp(&b.fps_per_w))
        .unwrap();
    println!(
        "\nbest FPS/W across all rows: {} ({:.2}) — paper: Ours W1A6 (4.05)",
        best.implementation, best.fps_per_w
    );
    // Power trend (paper: 9.9 → 8.7 → 7.8 W).
    println!("\npower (paper 9.9 / 8.7 / 7.8 W):");
    for r in rows5.iter() {
        report_metric(&format!("{} power", r.label), r.power_w, "W");
    }
}
