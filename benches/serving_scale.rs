//! Bench: multi-stream serving throughput vs worker-pool size.
//!
//! Compiles the DeiT-base preset for the ZCU102 at the paper's 24 FPS
//! target, then drives the multi-stream scheduler on the deterministic
//! virtual clock with analytic workers (per-frame latency from the
//! compiled design's `perf::cycles` prediction): 8 offered streams, pools
//! of 1→4 workers, one sweep per dispatch policy. Aggregate throughput,
//! p99 latency and SLA violations land in `BENCH_serving.json`.
//!
//! Because time is simulated, the numbers measure *scheduling* behaviour
//! (capacity, shedding, SLA pressure), not host speed — a full sweep
//! costs well under a second of wall time. The `sched_host_seconds`
//! metrics record what the scheduler itself costs the host.
//!
//! Run with: `cargo bench --bench serving_scale` (append `-- --quick`
//! for the CI-sized subset).

use vaqf::api::{Result, ServeClock, TargetSpec, TraceConfig};
use vaqf::coordinator::POLICY_NAMES;
use vaqf::util::bench::{bench_output_path, JsonReport};
use vaqf::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let quick = args.has_flag("quick");
    let (streams, frames) = if quick { (8usize, 240u64) } else { (8, 1200) };
    let mut report = JsonReport::new("serving_scale", if quick { "quick" } else { "full" });

    println!("=== serving scale: DeiT-base on zcu102, {streams} streams ===\n");
    let design = TargetSpec::new()
        .model_preset("deit-base")
        .device_preset("zcu102")
        .target_fps(24.0)
        .session()?
        .compile()?;
    let per_worker_fps = design.summary().fps;
    println!(
        "compiled {}: {per_worker_fps:.1} FPS per worker predicted\n",
        design.summary().label
    );

    // Each stream offers 30 FPS (8 × 30 = 240 aggregate): one worker is
    // deeply saturated, four still shy of the offered load, so throughput
    // must rise monotonically across the whole 1→4 sweep.
    let offered_fps = 30.0;
    for policy in POLICY_NAMES {
        println!("--- policy: {policy} ---");
        let mut last = 0.0f64;
        for workers in 1..=4usize {
            let t0 = std::time::Instant::now();
            let r = design
                .server()
                .streams(streams)
                .workers(workers)
                .policy(policy)
                .offered_fps(offered_fps)
                .frames(frames)
                .queue_depth(4)
                .sla_ms(80.0)
                .analytic()
                .clock(ServeClock::Virtual)
                .run()?;
            let host_s = t0.elapsed().as_secs_f64();
            let a = &r.aggregate;
            report.metric(
                &format!("{policy}/workers={workers} throughput"),
                a.achieved_fps,
                "fps",
            );
            report.metric(
                &format!("{policy}/workers={workers} p99_e2e"),
                a.e2e_latency.p99 * 1e3,
                "ms",
            );
            report.metric(
                &format!("{policy}/workers={workers} drop_rate"),
                a.drop_rate * 100.0,
                "%",
            );
            report.metric(
                &format!("{policy}/workers={workers} sla_violations"),
                a.sla_violations as f64,
                "frames",
            );
            report.metric(
                &format!("{policy}/workers={workers} sched_host_seconds"),
                host_s,
                "s",
            );
            if a.achieved_fps + 1e-9 < last {
                eprintln!(
                    "WARNING: throughput fell from {last:.1} to {:.1} FPS at {workers} workers",
                    a.achieved_fps
                );
            }
            last = a.achieved_fps;
        }
        println!();
    }

    // --- tracing overhead: the obs hook must be ~free when sampled ---
    // The same saturated scenario with and without a TraceSink attached;
    // best-of-k host time on each side (the min is the least-noise
    // estimate of the loop cost). CI gates the ratio at 1.02.
    println!("--- tracing overhead ---");
    let overhead_frames = 2400u64;
    let reps = 7;
    let bench_run = |traced: bool| -> Result<(f64, u64)> {
        let mut best = f64::INFINITY;
        let mut events = 0u64;
        for _ in 0..reps {
            let b = design
                .server()
                .streams(streams)
                .workers(4)
                .policy("least-loaded")
                .offered_fps(offered_fps)
                .frames(overhead_frames)
                .queue_depth(4)
                .sla_ms(80.0)
                .analytic()
                .clock(ServeClock::Virtual)
                .trace_config(TraceConfig {
                    layer_detail_every: 64,
                    ..TraceConfig::default()
                });
            let t0 = std::time::Instant::now();
            if traced {
                let (_, trace) = b.run_traced()?;
                events = trace.len() as u64;
            } else {
                b.run()?;
            }
            best = best.min(t0.elapsed().as_secs_f64());
        }
        Ok((best, events))
    };
    let (plain_s, _) = bench_run(false)?;
    let (traced_s, events) = bench_run(true)?;
    let ratio = traced_s / plain_s;
    println!(
        "disabled {plain_s:.4}s  traced {traced_s:.4}s  ratio {ratio:.3}×  ({events} events)"
    );
    report.metric("tracing/disabled_host_seconds", plain_s, "s");
    report.metric("tracing/enabled_host_seconds", traced_s, "s");
    report.metric("tracing/overhead_ratio", ratio, "x");
    report.metric("tracing/events", events as f64, "count");

    report
        .write(bench_output_path("BENCH_serving.json"))
        .map_err(vaqf::api::VaqfError::runtime)?;
    Ok(())
}
