//! Bench: fleet scale-out — pipelining vs replication at equal board
//! count.
//!
//! Compiles DeiT-base for the ZCU102 at the paper's 24 FPS target, then
//! carves 1→4 boards into each applicable topology preset (`replicated`,
//! `pipelined`, `mixed`) and replays the *same* Poisson trace — offered
//! at 95% of N single boards' aggregate throughput — through every fleet
//! on the virtual clock. Achieved FPS, drop rate, tail latency and mean
//! per-board utilization land in `BENCH_fleet.json`; CI gates on
//! (a) the best 4-board topology achieving ≥ 3× the single-board
//! throughput, (b) replication beating pipelining on these shallow
//! traces, and (c) two runs rendering byte-identical report JSON.
//!
//! Because time is simulated, the numbers measure the *fleet model*
//! (balancing, admission, stage backpressure), not host speed.
//!
//! Run with: `cargo bench --bench fleet_scale` (append `-- --quick`
//! for the CI-sized subset).

use vaqf::api::{Result, TargetSpec, TraceSpec};
use vaqf::util::bench::{bench_output_path, JsonReport};
use vaqf::util::cli::Args;

/// Presets that make sense at a board count (a 1-board pipeline or mix
/// is just a replica).
fn presets_at(boards: usize) -> &'static [&'static str] {
    match boards {
        0 | 1 => &["replicated"],
        2 => &["replicated", "pipelined"],
        _ => &["replicated", "pipelined", "mixed"],
    }
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let quick = args.has_flag("quick");
    let horizon_s = if quick { 1.0 } else { 4.0 };
    let board_counts: &[usize] = if quick { &[1, 4] } else { &[1, 2, 3, 4] };
    let mut report = JsonReport::new("fleet_scale", if quick { "quick" } else { "full" });

    println!("=== fleet scale: DeiT-base on zcu102, 1→4 boards ===\n");
    let design = TargetSpec::new()
        .model_preset("deit-base")
        .device_preset("zcu102")
        .target_fps(24.0)
        .session()?
        .compile()?;
    let single_fps = 1.0 / design.frame_latency_s();
    println!(
        "compiled {}: {:.1} FPS single-board\n",
        design.summary().label,
        single_fps
    );

    let mut single_achieved = 0.0f64;
    let mut best4 = 0.0f64;
    let mut replicated4 = 0.0f64;
    let mut pipelined4 = 0.0f64;
    for &boards in board_counts {
        // Offered load scales with the board budget, so every topology
        // at a given count faces the identical near-saturation trace.
        let trace = TraceSpec::poisson(0.95 * boards as f64 * single_fps, horizon_s, 42);
        for &preset in presets_at(boards) {
            let r = design
                .fleet()
                .boards(boards)
                .topology(preset)
                .balancer("least-outstanding")
                .trace(trace.clone())
                .run()?;
            let a = &r.aggregate;
            let mean_util = if r.units.is_empty() {
                0.0
            } else {
                r.units.iter().map(|u| u.utilization).sum::<f64>() / r.units.len() as f64
            };
            println!(
                "--- {boards} board(s), {preset}: {:.1} FPS achieved, \
                 {:.1}% dropped, p99 {:.2} ms ---",
                a.achieved_fps,
                100.0 * a.drop_rate,
                a.e2e_latency.p99 * 1e3
            );
            report.metric(
                &format!("boards={boards} {preset} achieved_fps"),
                a.achieved_fps,
                "fps",
            );
            report.metric(
                &format!("boards={boards} {preset} drop_rate"),
                a.drop_rate,
                "frac",
            );
            report.metric(
                &format!("boards={boards} {preset} p50_latency"),
                a.e2e_latency.p50 * 1e3,
                "ms",
            );
            report.metric(
                &format!("boards={boards} {preset} p99_latency"),
                a.e2e_latency.p99 * 1e3,
                "ms",
            );
            report.metric(
                &format!("boards={boards} {preset} mean_utilization"),
                mean_util,
                "frac",
            );
            if boards == 1 && preset == "replicated" {
                single_achieved = a.achieved_fps;
            }
            if boards == 4 {
                best4 = best4.max(a.achieved_fps);
                match preset {
                    "replicated" => replicated4 = a.achieved_fps,
                    "pipelined" => pipelined4 = a.achieved_fps,
                    _ => {}
                }
            }
        }
        println!();
    }

    report.metric("single-board achieved_fps", single_achieved, "fps");
    report.metric("best 4-board achieved_fps", best4, "fps");
    report.metric(
        "best 4-board scaling",
        if single_achieved > 0.0 { best4 / single_achieved } else { 0.0 },
        "x",
    );
    report.metric("replicated 4-board achieved_fps", replicated4, "fps");
    report.metric("pipelined 4-board achieved_fps", pipelined4, "fps");

    // Determinism probe: the 4-board mixed fleet under a flash crowd
    // with a mid-burst crash must render byte-identical JSON twice.
    let run_mixed = || -> Result<String> {
        let burst = TraceSpec::flash_crowd(
            0.5 * single_fps,
            5.0 * single_fps,
            0.3 * horizon_s,
            0.05 * horizon_s,
            0.2 * horizon_s,
            horizon_s,
            7,
        );
        let plan = vaqf::api::FaultPlan::new()
            .crash_at(0.4 * horizon_s, 0)
            .recovery(vaqf::api::RecoveryConfig {
                spares: 1,
                ..vaqf::api::RecoveryConfig::default()
            });
        Ok(design
            .fleet()
            .boards(4)
            .topology("mixed")
            .balancer("sla-weighted")
            .trace(burst)
            .faults(plan)
            .run()?
            .to_json()
            .pretty())
    };
    let deterministic = if run_mixed()? == run_mixed()? { 1.0 } else { 0.0 };
    report.metric("deterministic", deterministic, "bool");
    println!(
        "determinism probe: two fleet runs {}",
        if deterministic == 1.0 { "byte-identical" } else { "DIVERGED" }
    );

    report
        .write(bench_output_path("BENCH_fleet.json"))
        .map_err(vaqf::api::VaqfError::runtime)?;
    Ok(())
}
