//! Bench: pipeline-parallel sharding scale-out.
//!
//! Compiles DeiT-base for the ZCU102 at the paper's 24 FPS target, then
//! partitions the compiled design across 1→4 accelerator instances
//! (`vaqf::shard` balanced min-max partition + per-shard parameter
//! co-search) and drives the discrete-event pipeline simulator on the
//! deterministic virtual clock. Steady-state FPS, speedup over the
//! unsharded design, per-frame pipeline latency and per-stage resource
//! utilization land in `BENCH_sharding.json`; CI gates on the 2-shard
//! steady-state FPS being ≥ 1.5× the 1-shard number.
//!
//! Because time is simulated, the numbers measure the *pipeline model*
//! (stage balance, FIFO backpressure, fill/drain), not host speed; the
//! host cost of the per-shard co-search is reported separately.
//!
//! Run with: `cargo bench --bench sharding_scale` (append `-- --quick`
//! for the CI-sized subset).

use vaqf::api::{Result, ShardPolicy, TargetSpec};
use vaqf::util::bench::{bench_output_path, JsonReport};
use vaqf::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let quick = args.has_flag("quick");
    let frames = if quick { 120u64 } else { 600 };
    let mut report = JsonReport::new("sharding_scale", if quick { "quick" } else { "full" });

    println!("=== sharding scale: DeiT-base on zcu102, 1→4 shards ===\n");
    let design = TargetSpec::new()
        .model_preset("deit-base")
        .device_preset("zcu102")
        .target_fps(24.0)
        .session()?
        .compile()?;
    println!(
        "compiled {}: {:.1} FPS unsharded\n",
        design.summary().label,
        design.summary().fps
    );

    for shards in 1..=4usize {
        let t0 = std::time::Instant::now();
        let sharded = design.shards(shards)?;
        let cosearch_s = t0.elapsed().as_secs_f64();
        let r = sharded.report(frames);
        let p = &r.pipeline;
        println!(
            "--- {shards} shard(s): steady {:.1} FPS ({:.2}×) ---",
            p.steady_fps,
            p.steady_fps / design.summary().fps
        );
        report.metric(&format!("shards={shards} steady_fps"), p.steady_fps, "fps");
        report.metric(
            &format!("shards={shards} speedup_vs_unsharded"),
            p.steady_fps / design.summary().fps,
            "x",
        );
        report.metric(
            &format!("shards={shards} p50_latency"),
            p.latency.p50 * 1e3,
            "ms",
        );
        report.metric(
            &format!("shards={shards} p99_latency"),
            p.latency.p99 * 1e3,
            "ms",
        );
        report.metric(
            &format!("shards={shards} fill"),
            sharded.device.cycles_to_seconds(p.fill_cycles) * 1e3,
            "ms",
        );
        let max_pct = |f: fn(&vaqf::hw::UtilizationPct) -> f64| {
            sharded
                .stages
                .iter()
                .map(|s| f(&s.summary.utilization_pct))
                .fold(0.0f64, f64::max)
        };
        report.metric(
            &format!("shards={shards} max_stage_dsp"),
            max_pct(|u| u.dsp),
            "%",
        );
        report.metric(
            &format!("shards={shards} max_stage_lut"),
            max_pct(|u| u.lut),
            "%",
        );
        report.metric(
            &format!("shards={shards} max_stage_bram"),
            max_pct(|u| u.bram18k),
            "%",
        );
        report.metric(&format!("shards={shards} cosearch_host_seconds"), cosearch_s, "s");
        // The acceptance criterion "per-stage resource usage within the
        // divided budget" is a hard gate, not a warning: fail the bench
        // (and therefore CI) if any stage oversubscribes its board.
        for stage in &sharded.stages {
            let budget = sharded.per_shard_budget();
            let over_bram =
                stage.summary.utilization.bram18k + stage.fifo.bram18k > budget.bram18k;
            if !stage.summary.utilization.fits(budget) || over_bram {
                return Err(vaqf::api::VaqfError::config(format!(
                    "stage {} of the {shards}-shard design exceeds the per-shard \
                     budget (incl. FIFO BRAM)",
                    stage.index
                )));
            }
        }
        println!();
    }

    if !quick {
        println!("--- partition policies at 3 shards ---");
        for policy in [ShardPolicy::Balanced, ShardPolicy::Even, ShardPolicy::MinLatency] {
            let r = design.shards_with(3, policy)?.report(frames);
            report.metric(
                &format!("policy/{} steady_fps", policy.name()),
                r.pipeline.steady_fps,
                "fps",
            );
            report.metric(
                &format!("policy/{} p99_latency", policy.name()),
                r.pipeline.latency.p99 * 1e3,
                "ms",
            );
        }
        println!();
    }

    report
        .write(bench_output_path("BENCH_sharding.json"))
        .map_err(vaqf::api::VaqfError::runtime)?;
    Ok(())
}
