//! Bench: regenerate paper Table 5 — resource utilization and performance
//! of the VAQF-generated DeiT-base accelerators (W32A32 / W1A8 / W1A6) on
//! the simulated ZCU102 — and time the generation itself. Rows come from
//! one `vaqf::api` session.
//!
//! Run with: `cargo bench --bench table5_accelerators`

use vaqf::api::{render_table5, TargetSpec};
use vaqf::compiler::PAPER_TABLE5;
use vaqf::util::bench::{report_metric, Bench};

fn main() {
    let session = TargetSpec::new()
        .model_preset("deit-base")
        .device_preset("zcu102")
        .session()
        .expect("presets resolve");

    println!("== Table 5 regeneration (DeiT-base on simulated ZCU102) ==\n");
    let rows = session
        .table5(&[8, 6])
        .expect("paper precisions are feasible on zcu102");
    println!("{}", render_table5(&rows, &session.target().device));

    println!("paper-vs-measured:");
    for (label, paper_fps, paper_gops) in PAPER_TABLE5 {
        if let Some(r) = rows.iter().find(|r| r.label == label) {
            println!(
                "  {label:<8} paper {paper_fps:>5.1} FPS / {paper_gops:>6.1} GOPS   ours {:>5.1} FPS / {:>6.1} GOPS   ratio {:.2}",
                r.fps,
                r.gops,
                r.fps / paper_fps
            );
        }
    }

    // §6.3.1 derived claims.
    let base = &rows[0];
    let w1a8 = &rows[1];
    let w1a6 = &rows[2];
    println!("\nderived speedups (paper: 2.48x / 3.16x):");
    report_metric("W1A8 / W32A32 FPS", w1a8.fps / base.fps, "x");
    report_metric("W1A6 / W32A32 FPS", w1a6.fps / base.fps, "x");
    println!("compute efficiency (paper GOPS/DSP: 0.221 / 0.551 / 1.628):");
    for r in &rows {
        report_metric(&format!("{} GOPS/DSP", r.label), r.gops_per_dsp, "");
    }
    println!("compute efficiency (paper GOPS/kLUT: 2.88 / 6.02 / 6.60):");
    for r in &rows {
        report_metric(&format!("{} GOPS/kLUT", r.label), r.gops_per_klut, "");
    }

    // Fresh session per run: the session-level baseline cache would
    // otherwise hide the baseline search from the measurement.
    println!("\ntiming the generation pipeline:");
    let mut bench = Bench::heavy();
    bench.run("table5_rows (3 designs, full optimization)", || {
        let fresh = TargetSpec::new()
            .model_preset("deit-base")
            .device_preset("zcu102")
            .session()
            .expect("presets resolve");
        let _ = fresh.table5(&[8, 6]);
    });
}
