//! Bench: the hardware-side half of paper Table 2 — model space usage
//! under binarization (the accuracy half is produced by
//! `python -m compile.experiments table2`; if its JSON output exists we
//! print the measured accuracies beside the paper rows).
//!
//! Run with: `cargo bench --bench table2_space`

use vaqf::model::VitPreset;
use vaqf::util::bench::report_metric;
use vaqf::util::json::Json;

fn main() {
    println!("== Table 2: space usage (and accuracy, if experiments ran) ==\n");

    println!("{:<12} {:>14} {:>14} {:>10}", "model", "W32 (MB)", "W1 (MB)", "reduction");
    for preset in VitPreset::all() {
        let cfg = preset.config();
        let fp = cfg.structure(None).space_usage_bits() as f64 / 8e6;
        let bin = cfg.structure(Some(8)).space_usage_bits() as f64 / 8e6;
        println!(
            "{:<12} {:>14.1} {:>14.1} {:>9.1}x",
            cfg.name,
            fp,
            bin,
            fp / bin
        );
    }
    let base = VitPreset::DeiTBase.config();
    report_metric(
        "DeiT-base params (paper: 86M)",
        base.param_count() as f64 / 1e6,
        "M",
    );
    // Paper Table 2 counts the headline as 86M×32 → 86M×1 = 32× on the
    // (dominant) encoder weights; whole-model reduction is lower because
    // embeddings/head stay fp32.
    let enc_only = 32.0;
    report_metric("encoder-weight reduction (paper)", enc_only, "x");

    // Accuracy rows from the python experiment, if present.
    let path = "artifacts/experiments/table2.json";
    match std::fs::read_to_string(path) {
        Ok(text) => {
            let j = Json::parse(&text).expect("table2.json parse");
            println!("\nmeasured accuracy (reproduction scale, from {path}):");
            for row in j.get("rows").and_then(Json::as_arr).unwrap_or(&[]) {
                let regime = row.get("regime").and_then(Json::as_str).unwrap_or("?");
                let acc = row.get("test_acc").and_then(Json::as_f64).unwrap_or(0.0);
                println!("  micro-{regime:<8} {:.1}%", acc * 100.0);
            }
            println!(
                "paper (ImageNet): W32A32 81.8, W1A32 79.5, W1A8 77.6, W1A6 76.5"
            );
        }
        Err(_) => {
            println!("\n(accuracy rows not found — run `make table2` first)");
        }
    }
}
