//! Bench: cycle-level simulator vs analytical model (Eqs. 7–11) — the
//! Fig. 3 cross-validation — plus simulator throughput (simulated MACs/s,
//! the perf target from DESIGN.md §Perf).
//!
//! All designs come from `vaqf::api` sessions (compiled parameters, not
//! hand-picked tiles), so the comparison covers exactly what the compiler
//! emits.
//!
//! Run with: `cargo bench --bench sim_vs_model`

use vaqf::api::TargetSpec;
use vaqf::model::micro;
use vaqf::perf::model_cycles;
use vaqf::sim::model_timing;
use vaqf::util::bench::{report_metric, Bench};

fn main() {
    let deit = TargetSpec::new()
        .model_preset("deit-base")
        .device_preset("zcu102")
        .session()
        .expect("presets resolve");
    let dev = deit.target().device.clone();
    let model = deit.target().model.clone();

    println!("== timeline simulator vs analytical model (DeiT-base designs) ==\n");
    println!(
        "{:<10} {:>14} {:>14} {:>8}",
        "design", "analytic (cyc)", "timeline (cyc)", "ratio"
    );
    for bits in [None, Some(8), Some(6), Some(4)] {
        let design = deit
            .compile_for_bits(bits)
            .expect("paper precisions are feasible on zcu102");
        let s = model.structure(bits);
        let (analytic, per) = model_cycles(&s, design.params(), &dev);
        let host: u64 = per.iter().map(|c| c.host).sum();
        let engine = analytic - host;
        let (timeline, _) = model_timing(&s, design.params(), &dev);
        let label = bits.map(|b| format!("W1A{b}")).unwrap_or("W32A32".into());
        println!(
            "{:<10} {:>14} {:>14} {:>8.3}",
            label,
            engine,
            timeline,
            timeline as f64 / engine as f64
        );
    }

    println!("\n== functional simulator throughput (micro model) ==");
    let micro_session = TargetSpec::new()
        .model(micro())
        .device_preset("zcu102")
        .session()
        .expect("presets resolve");
    let macs = micro().structure(Some(8)).total_macs();
    let mut exec = micro_session
        .compile_for_bits(Some(8))
        .expect("micro W1A8 feasible")
        .simulator_with_seed(11);
    let patches = exec.weights().synthetic_patches(0);

    let mut bench = Bench::new();
    let r = bench.run("sim run_frame (micro W1A8)", || {
        let _ = exec.run_frame(&patches);
    });
    report_metric(
        "simulated MAC throughput",
        macs as f64 / r.mean_s() / 1e6,
        "M MACs/s",
    );

    let mut fp = micro_session
        .compile_for_bits(None)
        .expect("micro baseline feasible")
        .simulator_with_seed(11);
    let r2 = bench.run("sim run_frame (micro W32A32 fixed16)", || {
        let _ = fp.run_frame(&patches);
    });
    report_metric(
        "simulated MAC throughput (fixed16)",
        macs as f64 / r2.mean_s() / 1e6,
        "M MACs/s",
    );
}
