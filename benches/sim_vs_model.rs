//! Bench: cycle-level simulator vs analytical model (Eqs. 7–11) — the
//! Fig. 3 cross-validation — plus simulator throughput (simulated MACs/s,
//! the perf target from DESIGN.md §Perf).
//!
//! Run with: `cargo bench --bench sim_vs_model`

use vaqf::compiler::{optimize_baseline, optimize_for_bits};
use vaqf::hw::zcu102;
use vaqf::model::{deit_base, VitConfig};
use vaqf::perf::model_cycles;
use vaqf::sim::{generate_weights, model_timing, ModelExecutor};
use vaqf::util::bench::{report_metric, Bench};

fn micro() -> VitConfig {
    VitConfig {
        name: "micro".into(),
        image_size: 32,
        patch_size: 8,
        in_chans: 3,
        embed_dim: 32,
        depth: 2,
        num_heads: 4,
        mlp_ratio: 4,
        num_classes: 10,
    }
}

fn main() {
    let dev = zcu102();
    let model = deit_base();
    let base = optimize_baseline(&model.structure(None), &dev);

    println!("== timeline simulator vs analytical model (DeiT-base designs) ==\n");
    println!(
        "{:<10} {:>14} {:>14} {:>8}",
        "design", "analytic (cyc)", "timeline (cyc)", "ratio"
    );
    for bits in [None, Some(8), Some(6), Some(4)] {
        let s = model.structure(bits);
        let params = match bits {
            None => base,
            Some(b) => optimize_for_bits(&s, &base, &dev, b).unwrap().params,
        };
        let (analytic, per) = model_cycles(&s, &params, &dev);
        let host: u64 = per.iter().map(|c| c.host).sum();
        let engine = analytic - host;
        let (timeline, _) = model_timing(&s, &params, &dev);
        let label = bits.map(|b| format!("W1A{b}")).unwrap_or("W32A32".into());
        println!(
            "{:<10} {:>14} {:>14} {:>8.3}",
            label,
            engine,
            timeline,
            timeline as f64 / engine as f64
        );
    }

    println!("\n== functional simulator throughput (micro model) ==");
    let cfg = micro();
    let weights = generate_weights(&cfg, 11);
    let macs = cfg.structure(Some(8)).total_macs();
    let g_q = vaqf::perf::AcceleratorParams::g_q_for(64, 8);
    let params = vaqf::perf::AcceleratorParams {
        t_m: 16,
        t_n: 2,
        t_m_q: 16,
        t_n_q: 2 * g_q / 4,
        g: 4,
        g_q,
        p_h: 4,
        act_bits: Some(8),
    };
    let exec = ModelExecutor::new(weights.clone(), Some(8), params, dev.clone());
    let patches = weights.synthetic_patches(0);

    let mut bench = Bench::new();
    let r = bench.run("sim run_frame (micro W1A8)", || {
        let _ = exec.run_frame(&patches);
    });
    report_metric(
        "simulated MAC throughput",
        macs as f64 / r.mean_s() / 1e6,
        "M MACs/s",
    );

    let fp = ModelExecutor::new(
        weights.clone(),
        None,
        vaqf::perf::AcceleratorParams::baseline(16, 2, 4, 4),
        dev,
    );
    let r2 = bench.run("sim run_frame (micro W32A32 fixed16)", || {
        let _ = fp.run_frame(&patches);
    });
    report_metric(
        "simulated MAC throughput (fixed16)",
        macs as f64 / r2.mean_s() / 1e6,
        "M MACs/s",
    );
}
