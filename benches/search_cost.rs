//! Bench: the cost of the VAQF compilation step (paper §3: "several
//! minutes to several hours" with Vivado in the loop; our analytical
//! substitute runs in milliseconds-to-seconds) and the ≤4-round search
//! guarantee — each compile driven through a `vaqf::api` session.
//!
//! Run with: `cargo bench --bench search_cost`

use vaqf::api::TargetSpec;
use vaqf::util::bench::{report_metric, Bench};

fn main() {
    println!("== VAQF compilation-step cost ==\n");
    let mut bench = Bench::heavy();
    for model in ["deit-tiny", "deit-small", "deit-base"] {
        for dev_name in ["zcu102", "zcu111"] {
            let name = format!("compile {model} @24FPS on {dev_name}");
            // Fresh session per run: the session-level baseline cache
            // would otherwise drop the baseline search from the cost.
            bench.run(&name, || {
                let session = TargetSpec::new()
                    .model_preset(model)
                    .device_preset(dev_name)
                    .target_fps(24.0)
                    .session()
                    .expect("presets resolve");
                let _ = session.compile();
            });
        }
    }

    println!("\nsearch-round accounting (paper: ≤4 rounds for range 1..16):");
    for fps in [5.0, 12.0, 24.0, 30.0, 40.0] {
        let session = TargetSpec::new()
            .model_preset("deit-base")
            .device_preset("zcu102")
            .target_fps(fps)
            .session()
            .expect("presets resolve");
        match session.compile() {
            Ok(design) => {
                let out = design.outcome().expect("compile() records the search outcome");
                report_metric(
                    &format!("target {fps:>4.0} FPS → W1A{} rounds", out.act_bits),
                    (out.rounds.len() - 1) as f64,
                    "probes (excl. FR_max)",
                );
            }
            Err(e) => println!("  target {fps:>4.0} FPS infeasible: {e}"),
        }
    }
}
