//! Bench: the cost of the VAQF compilation step (paper §3: "several
//! minutes to several hours" with Vivado in the loop; our analytical
//! substitute runs in milliseconds-to-seconds) and the ≤4-round search
//! guarantee.
//!
//! Run with: `cargo bench --bench search_cost`

use vaqf::compiler::{compile, CompileRequest};
use vaqf::hw::{zcu102, zcu111};
use vaqf::model::VitPreset;
use vaqf::util::bench::{report_metric, Bench};

fn main() {
    println!("== VAQF compilation-step cost ==\n");
    let mut bench = Bench::heavy();
    for model in VitPreset::all() {
        for (dev_name, dev) in [("zcu102", zcu102()), ("zcu111", zcu111())] {
            let req = CompileRequest {
                model: model.config(),
                device: dev,
                target_fps: 24.0,
            };
            let name = format!("compile {} @24FPS on {dev_name}", req.model.name);
            bench.run(&name, || {
                let _ = compile(&req);
            });
        }
    }

    println!("\nsearch-round accounting (paper: ≤4 rounds for range 1..16):");
    for fps in [5.0, 12.0, 24.0, 30.0, 40.0] {
        let req = CompileRequest {
            model: VitPreset::DeiTBase.config(),
            device: zcu102(),
            target_fps: fps,
        };
        match compile(&req) {
            Ok(out) => {
                report_metric(
                    &format!("target {fps:>4.0} FPS → W1A{} rounds", out.act_bits),
                    (out.rounds.len() - 1) as f64,
                    "probes (excl. FR_max)",
                );
            }
            Err(e) => println!("  target {fps:>4.0} FPS infeasible: {e}"),
        }
    }
}
