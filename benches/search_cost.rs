//! Bench: the cost of the VAQF compilation step (paper §3: "several
//! minutes to several hours" with Vivado in the loop; our analytical
//! substitute runs in milliseconds) — and what the pruned, deduplicated,
//! parallel search engine plus the incremental `SearchCtx` memo buy over
//! the literal exhaustive sweep.
//!
//! Four measurements land in `BENCH_search.json`:
//!
//! * cold vs warm session compile (fresh session per run vs a session
//!   whose `SearchCtx` has already seen the design);
//! * the §5.3.2 per-precision search, pruned vs the exhaustive oracle,
//!   on DeiT-base @ ZCU102 — plus a `search_result_equal` bit asserting
//!   the two picked the same design;
//! * cold vs warm 2-way shard repartition (the failover path: a board
//!   dies and `co_search` re-runs — warm when the surviving shards'
//!   sub-searches are memo-served);
//! * the ≤4-round precision-search accounting from the paper.
//!
//! Run with: `cargo bench --bench search_cost` (append `-- --quick`
//! for the CI-sized subset).

use std::sync::Arc;

use vaqf::api::{Result, TargetSpec, VaqfError};
use vaqf::compiler::{optimize_for_bits_exhaustive, SearchCtx};
use vaqf::shard::{co_search_with_ctx, ShardPolicy};
use vaqf::util::bench::{bench_output_path, Bench, JsonReport};
use vaqf::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let quick = args.has_flag("quick");
    let mut report = JsonReport::new("search_cost", if quick { "quick" } else { "full" });
    let mut bench = Bench::heavy();

    // ---- cold vs warm session compile -----------------------------------
    println!("== compilation-step cost: cold vs warm sessions ==\n");
    let pairs: &[(&str, &str)] = if quick {
        &[("deit-base", "zcu102")]
    } else {
        &[
            ("deit-tiny", "zcu102"),
            ("deit-small", "zcu102"),
            ("deit-base", "zcu102"),
            ("deit-base", "zcu111"),
        ]
    };
    let mut cold_base_ms = 0.0f64;
    let mut warm_base_ms = 0.0f64;
    for &(model, dev_name) in pairs {
        // Fresh session per run: baseline + every probed precision search
        // from scratch — the pre-memo cost of one compile.
        let cold = bench.run(&format!("compile cold {model}@{dev_name}"), || {
            let session = TargetSpec::new()
                .model_preset(model)
                .device_preset(dev_name)
                .target_fps(24.0)
                .session()
                .expect("presets resolve");
            let _ = session.compile();
        });
        // One long-lived session: after the first compile, every probe is
        // a design-memo hit on the shared SearchCtx.
        let session = TargetSpec::new()
            .model_preset(model)
            .device_preset(dev_name)
            .target_fps(24.0)
            .session()
            .expect("presets resolve");
        let _ = session.compile();
        let warm = bench.run(&format!("compile warm {model}@{dev_name}"), || {
            let _ = session.compile();
        });
        report.result(&cold);
        report.result(&warm);
        if model == "deit-base" && dev_name == "zcu102" {
            cold_base_ms = cold.mean_s() * 1e3;
            warm_base_ms = warm.mean_s() * 1e3;
        }
    }
    report.metric("compile cold deit-base@zcu102", cold_base_ms, "ms");
    report.metric("compile warm deit-base@zcu102", warm_base_ms, "ms");
    report.metric(
        "warm compile speedup",
        if warm_base_ms > 0.0 { cold_base_ms / warm_base_ms } else { 0.0 },
        "x",
    );

    // ---- pruned vs exhaustive §5.3.2 search -----------------------------
    println!("\n== per-precision search: pruned+parallel vs exhaustive oracle ==\n");
    let model = vaqf::model::deit_base();
    let dev = vaqf::hw::zcu102();
    let warm_ctx = SearchCtx::new();
    let baseline = warm_ctx.optimize_baseline(&model.structure(None), &dev);
    let s8 = model.structure(Some(8));
    let exhaustive = bench.run("search exhaustive deit-base@zcu102 b8", || {
        let _ = optimize_for_bits_exhaustive(&s8, &baseline, &dev, 8);
    });
    // Fresh ctx per run: pruning + dedup + parallel fan-out, no memo.
    let pruned = bench.run("search pruned-cold deit-base@zcu102 b8", || {
        let ctx = SearchCtx::new();
        let _ = ctx.optimize_for_bits(&s8, &baseline, &dev, 8);
    });
    report.result(&exhaustive);
    report.result(&pruned);
    let speedup = exhaustive.mean_s() / pruned.mean_s();
    report.metric("exhaustive compile-step", exhaustive.mean_s() * 1e3, "ms");
    report.metric("pruned compile-step", pruned.mean_s() * 1e3, "ms");
    report.metric("pruned-vs-exhaustive speedup", speedup, "x");
    println!("\npruned-vs-exhaustive speedup: {speedup:.1}x");

    let want = optimize_for_bits_exhaustive(&s8, &baseline, &dev, 8).ok();
    let got = warm_ctx.optimize_for_bits(&s8, &baseline, &dev, 8).ok();
    let equal = match (&want, &got) {
        (Some(w), Some(g)) => {
            w.params == g.params
                && w.adjustments == g.adjustments
                && w.summary.cycles_per_frame == g.summary.cycles_per_frame
        }
        (None, None) => true,
        _ => false,
    };
    report.metric("search_result_equal", if equal { 1.0 } else { 0.0 }, "bool");
    println!(
        "result equality: pruned {} exhaustive",
        if equal { "==" } else { "DIVERGED FROM" }
    );

    // ---- cold vs warm shard repartition ---------------------------------
    println!("\n== 2-way shard repartition: cold vs memo-warm ==\n");
    let (part_model, part_dev) = if quick {
        (vaqf::model::deit_tiny(), dev.clone())
    } else {
        (vaqf::model::deit_base(), dev.clone())
    };
    let part_ctx = Arc::new(SearchCtx::new());
    let part_base = part_ctx.optimize_baseline(&part_model.structure(None), &part_dev);
    let reference = part_ctx
        .optimize_for_bits(&part_model.structure(Some(8)), &part_base, &part_dev, 8)
        .map_err(VaqfError::runtime)?;
    let repart_cold = bench.run(&format!("repartition cold {}", part_model.name), || {
        let _ = co_search_with_ctx(
            &part_model,
            &part_dev,
            Some(8),
            &reference,
            2,
            ShardPolicy::Balanced,
            Arc::new(SearchCtx::new()),
        );
    });
    // Warm the shared ctx once, then every repartition is the failover
    // fast path: per-stage searches served from the memo.
    let _ = co_search_with_ctx(
        &part_model,
        &part_dev,
        Some(8),
        &reference,
        2,
        ShardPolicy::Balanced,
        part_ctx.clone(),
    );
    let repart_warm = bench.run(&format!("repartition warm {}", part_model.name), || {
        let _ = co_search_with_ctx(
            &part_model,
            &part_dev,
            Some(8),
            &reference,
            2,
            ShardPolicy::Balanced,
            part_ctx.clone(),
        );
    });
    report.result(&repart_cold);
    report.result(&repart_warm);
    report.metric("repartition cold", repart_cold.mean_s() * 1e3, "ms");
    report.metric("repartition warm", repart_warm.mean_s() * 1e3, "ms");
    report.metric(
        "warm repartition speedup",
        if repart_warm.mean_s() > 0.0 {
            repart_cold.mean_s() / repart_warm.mean_s()
        } else {
            0.0
        },
        "x",
    );

    // ---- SearchCtx counter snapshot -------------------------------------
    // The warm per-precision ctx from the §5.3.2 section above: how much
    // work the engine did vs skipped (memo hits, pruned planes, dedup'd
    // container classes) for one full b8 search.
    let stats = warm_ctx.stats();
    println!(
        "\nsearch counters (warm b8 ctx): {ev} evals, {ph} point hits, \
         {dh} design hits, {pp} planes pruned, {cd} classes deduped",
        ev = stats.point_evals,
        ph = stats.point_hits,
        dh = stats.design_hits,
        pp = stats.planes_pruned,
        cd = stats.classes_deduped,
    );
    report.metric("search/point_evals", stats.point_evals as f64, "count");
    report.metric("search/point_hits", stats.point_hits as f64, "count");
    report.metric("search/design_hits", stats.design_hits as f64, "count");
    report.metric("search/baseline_hits", stats.baseline_hits as f64, "count");
    report.metric("search/planes_pruned", stats.planes_pruned as f64, "count");
    report.metric(
        "search/classes_deduped",
        stats.classes_deduped as f64,
        "count",
    );

    // ---- search-round accounting ----------------------------------------
    println!("\nsearch-round accounting (paper: ≤4 rounds for range 1..16):");
    for fps in [5.0, 12.0, 24.0, 30.0, 40.0] {
        let session = TargetSpec::new()
            .model_preset("deit-base")
            .device_preset("zcu102")
            .target_fps(fps)
            .session()
            .expect("presets resolve");
        match session.compile() {
            Ok(design) => {
                let out = design.outcome().expect("compile() records the search outcome");
                let rounds = (out.rounds.len() - 1) as f64;
                println!(
                    "  target {fps:>4.0} FPS → W1A{} in {rounds} probes (excl. FR_max)",
                    out.act_bits
                );
                report.metric(&format!("rounds @{fps:.0}fps"), rounds, "probes");
            }
            Err(e) => println!("  target {fps:>4.0} FPS infeasible: {e}"),
        }
    }

    report
        .write(bench_output_path("BENCH_search.json"))
        .map_err(VaqfError::runtime)?;
    Ok(())
}
